//! Rank-aware training loop over a [`crate::comm::transport`] group
//! (ISSUE 4): each OS process (or thread, under the in-proc backend)
//! materializes **one** worker replica and drives the same
//! `DistOptimizer` step bodies the in-process [`super::Trainer`] runs —
//! with every cross-worker reduction going through the framed
//! transport collectives instead of the engine.
//!
//! The deployment contract (DESIGN.md §Transport): a [`DistSpec`] run
//! over N ranks — `zo-adam launch --ranks N --transport {inproc,tcp}`
//! — produces **bitwise identical** parameters, per-step losses and
//! ledger round counts to [`run_local`] with `ExecMode::Threaded(N)`
//! (or `Sequential`; the engine modes are themselves bitwise equal).
//! [`check_parity`] pins that equality; `tests/transport_parity.rs`
//! and `ci.sh`'s TCP smoke run it for every optimizer family.
//!
//! The per-rank ledger counts the **actual framed bytes** each
//! reduction moved (header + payload), not the analytic estimate —
//! this is where the paper's wire-volume claims become measurements of
//! real bytes on a real socket.

use crate::comm::transport::{RankLink, TransportError};
use crate::comm::volume::VolumeLedger;
use crate::comm::{ReduceBackend, Topology};
use crate::grad::synthetic::NoisyQuadratic;
use crate::grad::GradientSource;
use crate::optim::policy::{SyncPolicy, SyncSchedule, VarSchedule};
use crate::optim::{
    Adam, ConstLr, DistOptimizer, FrozenVarAdam, Hyper, MomentumSgd, NaiveOneBitAdam, SignSgd,
    ZeroOneAdam,
};
use crate::runtime::checkpoint::{
    read_shard, shard_info, write_shard, CheckpointCfg, CheckpointError, RunMeta, StateReader,
    StateWriter,
};
use crate::runtime::manifest::RunManifest;

use super::engine::{Engine, ExecMode};
use super::trainer::{NoObserver, RunResult, Trainer, TrainerConfig};

/// Optimizer families a distributed run can launch — the same set the
/// engine-parity suite pins, plus the no-local-steps ablation.
pub const FAMILIES: [&str; 7] = [
    "adam",
    "momentum-sgd",
    "signsgd-ef",
    "naive-1bit-adam",
    "1bit-adam",
    "01adam",
    "01adam-nolocal",
];

/// Everything that defines one distributed training run. Root and
/// workers must construct identical specs (the CLI passes the same
/// arguments to every `zo-adam worker`); the [`DistSpec::fingerprint`]
/// rides in the TCP handshake so a mismatched worker is rejected
/// before any training traffic moves.
#[derive(Debug, Clone, PartialEq)]
pub struct DistSpec {
    /// One of [`FAMILIES`].
    pub family: String,
    /// Model dimension.
    pub d: usize,
    pub steps: u64,
    /// Ranks in the group == logical data-parallel workers.
    pub world: usize,
    /// Data seed; rank r draws worker-r noise streams, exactly like
    /// in-process worker r.
    pub seed: u64,
    pub lr: f64,
    /// Condition number of the synthetic quadratic objective.
    pub kappa: f64,
    /// Per-worker gradient noise σ.
    pub sigma: f32,
    /// Constant initial parameter value.
    pub init: f32,
    /// Reduction schedule shape (`--topology`). Part of the
    /// fingerprint: the tree trajectory differs from the star's, so
    /// every rank — and the parity reference — must agree on it.
    pub topology: Topology,
}

impl Default for DistSpec {
    fn default() -> Self {
        DistSpec {
            family: "01adam".to_string(),
            d: 2 * crate::comm::SERVER_CHUNK + 777,
            steps: 60,
            world: 4,
            seed: 0,
            lr: 0.01,
            kappa: 5.0,
            sigma: 0.1,
            init: 0.8,
            topology: Topology::Star,
        }
    }
}

impl DistSpec {
    /// FNV-1a over the canonical field encoding — the handshake token
    /// that catches workers launched with different arguments.
    pub fn fingerprint(&self) -> u64 {
        let canon = format!(
            "{}|{}|{}|{}|{}|{:016x}|{:016x}|{:08x}|{:08x}|{}",
            self.family,
            self.d,
            self.steps,
            self.world,
            self.seed,
            self.lr.to_bits(),
            self.kappa.to_bits(),
            self.sigma.to_bits(),
            self.init.to_bits(),
            // normalized: `--topology tree9` at world 4 *is* the star
            // schedule, so spelling it either way must still handshake
            self.topology.normalized(self.world),
        );
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in canon.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// The synthetic objective every rank (and the local reference)
    /// trains on. Pure per `(worker, t)` stream — rank r computing
    /// worker r's gradient is bitwise the in-process computation.
    pub fn source(&self) -> NoisyQuadratic {
        NoisyQuadratic::new(self.d, self.kappa, self.sigma, self.seed)
    }

    /// The identity a checkpoint manifest records (ISSUE 10): the spec
    /// fingerprint plus the human-readable fields the loader re-checks
    /// one by one, so a mismatched resume dies with a *named* field
    /// rather than an opaque fingerprint diff.
    pub fn run_meta(&self) -> RunMeta {
        RunMeta {
            fingerprint: self.fingerprint(),
            family: self.family.clone(),
            d: self.d,
            steps: self.steps,
            world: self.world,
            topology: self.topology.normalized(self.world).to_string(),
        }
    }

    /// Build the family's optimizer over `n_workers` materialized
    /// replicas: `world` for the in-process reference, 1 per transport
    /// rank. All schedule parameters derive deterministically from the
    /// spec, so both shapes run identical policies.
    pub fn build_optimizer(&self, n_workers: usize) -> Option<Box<dyn DistOptimizer>> {
        let init = vec![self.init; self.d];
        let h = Hyper::default();
        let lr: Box<ConstLr> = Box::new(ConstLr(self.lr));
        Some(match self.family.as_str() {
            "adam" => Box::new(Adam::new(init, n_workers, h, lr)),
            "momentum-sgd" => Box::new(MomentumSgd::new(init, n_workers, 0.9, lr)),
            "signsgd-ef" => Box::new(SignSgd::new(init, n_workers, lr)),
            "naive-1bit-adam" => Box::new(NaiveOneBitAdam::new(init, n_workers, h, lr)),
            "1bit-adam" => {
                let t0 = (self.steps / 8).max(2);
                Box::new(FrozenVarAdam::onebit_adam(init, n_workers, h, lr, t0))
            }
            "01adam" => Box::new(ZeroOneAdam::new(
                init,
                n_workers,
                h,
                lr,
                VarSchedule::paper(),
                SyncSchedule::scaled_bert(self.steps),
            )),
            "01adam-nolocal" => Box::new(ZeroOneAdam::new(
                init,
                n_workers,
                h,
                lr,
                VarSchedule::paper(),
                SyncSchedule::new(SyncPolicy::Always),
            )),
            _ => return None,
        })
    }
}

/// What one rank's training loop produced. Only rank 0 carries the
/// aggregated fields (gathered/averaged params, the loss trace, the
/// evaluation); every rank carries its own ledger — the round counts
/// are identical across ranks by construction.
pub struct RankResult {
    pub rank: usize,
    pub world: usize,
    /// Worker-order mean of the final replicas (root only; exact f32
    /// gather — see `RankLink::gather_params_mean`).
    pub final_params: Vec<f32>,
    /// Mean loss of the last step (root only; NaN elsewhere).
    pub final_loss: f64,
    /// Held-out loss at the final mean params (root only).
    pub final_eval: Option<f32>,
    /// Per-step worker-order mean losses (root only).
    pub losses: Vec<f64>,
    /// Actual framed bytes + round counts this rank's reductions moved.
    pub ledger: VolumeLedger,
    /// Successful transport-level drop-recoveries (reconnect + resume)
    /// this rank performed. Zero on a healthy network; chaos scenarios
    /// assert it is nonzero to prove a drop was actually recovered.
    pub resumes: u64,
    pub wall_s: f64,
}

/// Per-rank runtime options that live **outside** the fingerprinted
/// [`DistSpec`]: transport deadlines and chaos hooks may legitimately
/// differ across ranks (a tighter deadline on one rank, a fault plan
/// on another) without being a different *run* — they never change
/// the trajectory, only how failures surface.
#[derive(Debug, Clone, Default)]
pub struct RankOpts {
    /// Per-recv deadline pushed onto the link (`None` = the backend's
    /// default). A peer silent for longer is a typed
    /// `TransportError::Timeout`, never an indefinite block.
    pub recv_deadline: Option<std::time::Duration>,
    /// Chaos hook (`zo-adam worker --die-at-step`): abort the process
    /// at the start of step `t` — a real SIGABRT mid-round, for the
    /// kill-a-rank scenarios in `tests/chaos_shutdown.rs`.
    pub die_at_step: Option<u64>,
    /// Arm this rank's flight recorder and, on success, append the
    /// rank's JSONL run-event stream to this file (`--trace-out`).
    /// Best-effort export: a write failure is reported, never fatal —
    /// and the recorder never feeds back into the trajectory, so a
    /// traced run stays bitwise identical to an untraced one.
    pub trace_out: Option<String>,
    /// Arm the recorder and print this rank's step/round/recovery
    /// records to stdout as JSONL lines (`--events`).
    pub events: bool,
    /// Write per-rank checkpoint shards under this directory
    /// (`--checkpoint-dir`). Like the other options, checkpointing
    /// never feeds back into the trajectory — a checkpointed run is
    /// bitwise identical to an unchecked one.
    pub checkpoint_dir: Option<String>,
    /// Cut a checkpoint every K completed steps (`--checkpoint-every`;
    /// 0 = never, even when a directory is set).
    pub checkpoint_every: u64,
    /// Resume from the manifest in this directory (`--resume`). The
    /// manifest is fingerprint-checked against the spec: a resume into
    /// a different family/world/topology dies typed at load, before
    /// any training traffic moves.
    pub resume: Option<String>,
}

impl RankOpts {
    /// Does this rank record a trace at all?
    pub fn tracing(&self) -> bool {
        self.trace_out.is_some() || self.events
    }
}

/// [`run_rank_opts`] with default options — the common path.
pub fn run_rank(link: &mut RankLink, spec: &DistSpec) -> Result<RankResult, TransportError> {
    run_rank_opts(link, spec, &RankOpts::default())
}

/// Run one rank of a distributed training job to completion. The same
/// function serves the root (rank 0) and every worker — the collective
/// legs differ inside the transport, not here.
///
/// Retry policy: there is deliberately **no retry loop at this level**.
/// Recoverable faults (a dropped root edge) are healed *inside* the
/// transport at frame granularity, where the resume protocol knows
/// exactly which bytes the peer is missing; by the time an error
/// reaches this loop it is typed and terminal — re-entering a
/// collective here would re-send frames the schedule already counted
/// and desynchronize every peer's seq. Fail fast, report the typed
/// error, let the launcher's process guard clean up.
pub fn run_rank_opts(
    link: &mut RankLink,
    spec: &DistSpec,
    opts: &RankOpts,
) -> Result<RankResult, TransportError> {
    assert_eq!(
        link.world(),
        spec.world,
        "transport group size does not match the run spec"
    );
    link.set_topology(spec.topology.normalized(spec.world));
    if let Some(d) = opts.recv_deadline {
        link.set_recv_deadline(Some(d));
    }
    let rank = link.rank();
    if opts.tracing() {
        crate::obs::arm(crate::obs::DEFAULT_CAPACITY);
    }
    let mut step_records: Vec<crate::obs::Record> = Vec::new();
    let d = spec.d;
    let mut src = spec.source();
    let mut opt = spec
        .build_optimizer(1)
        .unwrap_or_else(|| panic!("unknown optimizer family '{}'", spec.family));
    // Local per-replica math is engine-mode independent (DESIGN.md §3),
    // so ranks run sequentially; parallelism across workers is the
    // process fan-out itself.
    let eng = Engine::sequential();
    let mut grads = vec![vec![0.0f32; d]];
    let mut ledger = VolumeLedger::new(d);
    let mut losses = Vec::new();
    let wall = crate::util::Stopwatch::start();

    // Checkpoint/resume (ISSUE 10). Resume restores this rank's shard
    // *before* the start barrier: it is pure local file I/O, and every
    // rank independently verifies the same manifest, so a rank whose
    // shard is corrupt (or whose spec mismatches) dies typed before any
    // reduction traffic moves.
    let meta = spec.run_meta();
    let mut start_t = 0u64;
    if let Some(dir) = &opts.resume {
        let ck = CheckpointCfg {
            dir: dir.clone(),
            every: 0,
            resume: true,
            meta: meta.clone(),
        };
        start_t = resume_rank_checkpoint(rank, spec, &ck, opt.as_mut(), &mut ledger, &mut losses)
            .map_err(|e| TransportError::Checkpoint(e.to_string()))?;
    }
    let ckpt_cfg = opts.checkpoint_dir.as_ref().map(|dir| CheckpointCfg {
        dir: dir.clone(),
        every: opts.checkpoint_every,
        resume: false,
        meta,
    });

    // Everyone reaches the loop before any reduction traffic starts —
    // and the barrier itself is exercised every run.
    link.barrier()?;

    for t in start_t..spec.steps {
        if opts.die_at_step == Some(t) {
            // Chaos hook: a hard, mid-round death — not a clean exit —
            // so survivor behavior is tested against the real thing.
            eprintln!("[chaos] rank {rank} aborting at step {t} (--die-at-step)");
            std::process::abort();
        }
        // Rank r *is* worker r: same params, same noise stream, same
        // gradient bits as in-process worker r.
        crate::obs::begin(crate::obs::PhaseId::Step);
        let loss = src.grad(opt.params(0), rank, t, &mut grads[0]);
        let info = opt.step_comm(t, &grads, &eng, &mut ReduceBackend::Transport(&mut *link))?;
        ledger.record_step(&info.rounds);
        // Control-plane loss gather (not ledgered): the root's trace is
        // the worker-order f64 mean the in-process trainer logs.
        if let Some(mean) = link.gather_mean_loss(loss)? {
            losses.push(mean);
        }
        crate::obs::end(crate::obs::PhaseId::Step);
        if opts.tracing() {
            step_records.push(crate::obs::Record::Step {
                rank,
                t,
                loss: loss as f64,
                t_ns: crate::obs::now_ns().unwrap_or(0),
            });
        }
        // Cut a checkpoint after the step completes: every rank writes
        // its shard, then (barrier) the root digests all shards into
        // the manifest, then (barrier) everyone proceeds — so a
        // manifest on disk always describes a *complete* shard set.
        if let Some(ck) = &ckpt_cfg {
            if ck.every > 0 && (t + 1) % ck.every == 0 {
                save_rank_checkpoint(link, spec, ck, opt.as_ref(), &ledger, &losses, t + 1)?;
            }
        }
    }

    // Final model: shared-state families hold identical replicas on
    // every rank (root copies its own); per-replica families gather
    // exact f32 params and average in rank order — both reproduce
    // `DistOptimizer::mean_params` bit for bit.
    let mut final_params = Vec::new();
    if opt.shared_state() {
        if rank == 0 {
            final_params = vec![0.0f32; d];
            opt.mean_params(&mut final_params);
        }
    } else {
        let mut out = vec![0.0f32; d];
        if link.gather_params_mean(opt.params(0), &mut out)? {
            final_params = out;
        }
    }

    let (final_loss, final_eval) = if rank == 0 {
        (
            losses.last().copied().unwrap_or(f64::NAN),
            src.eval_loss(&final_params),
        )
    } else {
        (f64::NAN, None)
    };

    if opts.tracing() {
        flush_trace(link, spec, opts, rank, &ledger, step_records);
    }

    Ok(RankResult {
        rank,
        world: spec.world,
        final_params,
        final_loss,
        final_eval,
        losses,
        ledger,
        resumes: link.resumes(),
        wall_s: wall.elapsed_secs(),
    })
}

/// Serialize this rank's snapshot — replica optimizer state (with its
/// slice of the EF error memory), the byte-true ledger, and the loss
/// trace (root-only content; empty elsewhere) — and publish it with
/// the two-barrier protocol described at the call site. Checkpoint
/// errors cross the transport boundary as
/// [`TransportError::Checkpoint`], so the launcher's process guard
/// handles them like any other fatal rank error.
fn save_rank_checkpoint(
    link: &mut RankLink,
    spec: &DistSpec,
    ck: &CheckpointCfg,
    opt: &dyn DistOptimizer,
    ledger: &VolumeLedger,
    losses: &[f64],
    step: u64,
) -> Result<(), TransportError> {
    let ckerr = |e: CheckpointError| TransportError::Checkpoint(e.to_string());
    let rank = link.rank();
    let mut w = StateWriter::new();
    w.put_str("rank");
    opt.save_state(&mut w);
    ledger.save_state(&mut w);
    w.put_f64s(losses);
    write_shard(&ck.dir, rank, step, w.bytes()).map_err(ckerr)?;
    link.barrier()?;
    if rank == 0 {
        let mut shards = Vec::with_capacity(spec.world);
        for r in 0..spec.world {
            shards.push(shard_info(&ck.dir, r).map_err(ckerr)?.into());
        }
        RunManifest::new(step, ck.meta.clone(), "per-rank", shards)
            .write(&ck.dir)
            .map_err(ckerr)?;
    }
    link.barrier()?;
    Ok(())
}

/// Restore this rank's shard from a `--resume` directory; returns the
/// step the loop resumes at. Verification order: manifest self-digest
/// (inside [`RunManifest::load`]), then the spec identity field by
/// field, then this rank's shard bytes against the manifest digest,
/// then the structural decode of the state itself.
fn resume_rank_checkpoint(
    rank: usize,
    spec: &DistSpec,
    ck: &CheckpointCfg,
    opt: &mut dyn DistOptimizer,
    ledger: &mut VolumeLedger,
    losses: &mut Vec<f64>,
) -> Result<u64, CheckpointError> {
    let man = RunManifest::load(&ck.dir)?;
    man.check(&ck.meta, "per-rank", spec.world)?;
    let entry = man.shard(rank)?;
    let (step, body) = read_shard(&ck.dir, rank, Some(entry.digest))?;
    if step != man.step {
        return Err(CheckpointError::StepMismatch { manifest: man.step, shard: step });
    }
    let mut r = StateReader::new(&body, &entry.file);
    r.expect_tag("rank")?;
    opt.load_state(&mut r)?;
    ledger.load_state(&mut r)?;
    *losses = r.take_f64s()?;
    r.finish()?;
    Ok(step)
}

/// Export one successful rank's run-event stream (ISSUE 9): a meta
/// record, the recorder's phase events, then the step/round/recovery
/// records. Only reached on success — a failed rank aborts without
/// flushing, so an exported file never carries a stream cut mid-span.
/// Export is best-effort: an I/O failure is reported on stderr and
/// never fails the run.
fn flush_trace(
    link: &RankLink,
    spec: &DistSpec,
    opts: &RankOpts,
    rank: usize,
    ledger: &VolumeLedger,
    step_records: Vec<crate::obs::Record>,
) {
    use crate::obs::{self, Record};
    let t_ns = obs::now_ns().unwrap_or(0);
    let Some(rec) = obs::disarm() else { return };
    let mut records = Vec::with_capacity(rec.len() + step_records.len() + 3);
    records.push(Record::Meta {
        rank,
        world: spec.world,
        family: spec.family.clone(),
        d: spec.d,
        steps: spec.steps,
        topology: spec.topology.normalized(spec.world).to_string(),
    });
    for ev in rec.events() {
        records.push(Record::from_event(rank, &ev));
    }
    records.extend(step_records);
    records.push(Record::Round {
        rank,
        rounds: ledger.rounds_total(),
        bytes: ledger.bytes_total,
        compressed: ledger.onebit_rounds,
    });
    records.push(Record::Recovery { rank, resumes: link.resumes(), t_ns });
    if opts.events {
        for r in &records {
            if matches!(r, Record::Step { .. } | Record::Round { .. } | Record::Recovery { .. }) {
                println!("{}", r.to_json().to_string_compact());
            }
        }
    }
    if let Some(path) = &opts.trace_out {
        if let Err(e) = obs::events::append_to_file(path, &records) {
            eprintln!("[obs] rank {rank}: trace export to {path} failed: {e}");
        }
    }
}

/// The single-process reference for [`check_parity`]: the ordinary
/// [`Trainer`] over `spec.world` materialized workers.
pub fn run_local(spec: &DistSpec, exec: ExecMode) -> RunResult {
    let mut src = spec.source();
    let mut opt = spec
        .build_optimizer(spec.world)
        .unwrap_or_else(|| panic!("unknown optimizer family '{}'", spec.family));
    let cfg = TrainerConfig {
        steps: spec.steps,
        log_every: 1,
        eval_every: 0,
        fabric: None,
        sim_gpus: 0,
        compute_ms: 0.0,
        exec,
        topology: spec.topology,
        verbose: false,
    };
    Trainer::run(&mut src, opt.as_mut(), &cfg, &mut NoObserver)
}

/// Run the whole group on threads over the in-proc channel backend;
/// results indexed by rank. The default `zo-adam launch` path and what
/// the parity tests drive.
pub fn launch_inproc(spec: &DistSpec) -> Result<Vec<RankResult>, TransportError> {
    launch_inproc_opts(spec, &RankOpts::default())
}

/// [`launch_inproc`] with per-rank options — every rank thread runs
/// the same `opts` (each arms its own thread-local recorder when
/// tracing; `trace_out` appends are serialized by the exporter).
pub fn launch_inproc_opts(
    spec: &DistSpec,
    opts: &RankOpts,
) -> Result<Vec<RankResult>, TransportError> {
    let links = crate::comm::transport::inproc::group_topo(
        spec.world,
        spec.topology.normalized(spec.world),
    );
    std::thread::scope(|s| {
        let handles: Vec<_> = links
            .into_iter()
            .map(|tp| {
                s.spawn(move || {
                    let mut link = RankLink::new(Box::new(tp));
                    run_rank_opts(&mut link, spec, opts)
                })
            })
            .collect();
        let mut out = Vec::with_capacity(spec.world);
        for h in handles {
            out.push(h.join().expect("rank thread panicked")?);
        }
        Ok(out)
    })
}

/// The subsystem's core contract, as an executable check: rank 0's
/// distributed result must equal the in-process run **bit for bit** —
/// final parameters, every step's mean loss, the final evaluation, and
/// the ledger's round counts. (Byte totals intentionally differ: the
/// distributed ledger counts real framed bytes, headers and
/// word-aligned sign payloads included.)
pub fn check_parity(dist: &RankResult, local: &RunResult) -> Result<(), String> {
    if dist.rank != 0 {
        return Err("parity is checked against rank 0's result".to_string());
    }
    if dist.final_params.len() != local.final_params.len() {
        return Err(format!(
            "final param dim {} vs local {}",
            dist.final_params.len(),
            local.final_params.len()
        ));
    }
    for (j, (a, b)) in dist.final_params.iter().zip(&local.final_params).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!("final_params[{j}]: {a} (dist) != {b} (local)"));
        }
    }
    let dl = &dist.ledger;
    let ll = &local.ledger;
    if (dl.steps, dl.fp_rounds, dl.onebit_rounds, dl.skipped_steps)
        != (ll.steps, ll.fp_rounds, ll.onebit_rounds, ll.skipped_steps)
    {
        return Err(format!(
            "ledger rounds differ: dist (steps {}, fp {}, 1bit {}, skipped {}) vs local \
             (steps {}, fp {}, 1bit {}, skipped {})",
            dl.steps, dl.fp_rounds, dl.onebit_rounds, dl.skipped_steps, ll.steps, ll.fp_rounds,
            ll.onebit_rounds, ll.skipped_steps
        ));
    }
    if dist.losses.len() != local.log.records.len() {
        return Err(format!(
            "loss trace length {} vs local {} (local must log every step)",
            dist.losses.len(),
            local.log.records.len()
        ));
    }
    for (mean, rec) in dist.losses.iter().zip(&local.log.records) {
        if mean.to_bits() != rec.loss.to_bits() {
            return Err(format!(
                "loss@t={}: {mean} (dist) != {} (local)",
                rec.t, rec.loss
            ));
        }
    }
    match (dist.final_eval, local.final_eval) {
        (Some(a), Some(b)) if a.to_bits() == b.to_bits() => {}
        (None, None) => {}
        (a, b) => return Err(format!("final_eval {a:?} (dist) != {b:?} (local)")),
    }
    Ok(())
}

/// RAII guard over the `zo-adam worker` OS processes a TCP launch
/// spawns (ISSUE 5 satellite). Before this guard, a failure between
/// spawn and handshake completion leaked live workers two ways: a
/// spawn error halfway through the worker loop `?`-propagated past the
/// reap loop entirely, and a root error only `wait()`ed — potentially
/// for the workers' full 30 s handshake retry window. The guard owns
/// every spawned child from the moment it exists:
///
/// * [`WorkerChildren::reap`] — the happy path: block until every
///   worker exits, report the failures;
/// * [`WorkerChildren::shutdown`] — the root-error path: a bounded
///   grace period for self-exits (a worker's own exit status is the
///   diagnosis; the root's error is often just the symptom), then
///   kill + reap whatever is left;
/// * `Drop` — the backstop for any path that unwinds or `?`-returns
///   past both: kill + reap unconditionally, so no error path can
///   leave a live worker behind (`tests/launch_cleanup.rs`).
#[derive(Default)]
pub struct WorkerChildren {
    children: Vec<(usize, std::process::Child)>,
}

impl WorkerChildren {
    pub fn new() -> Self {
        WorkerChildren { children: Vec::new() }
    }

    /// Take ownership of a freshly spawned worker.
    pub fn push(&mut self, rank: usize, child: std::process::Child) {
        self.children.push((rank, child));
    }

    pub fn len(&self) -> usize {
        self.children.len()
    }

    pub fn is_empty(&self) -> bool {
        self.children.is_empty()
    }

    /// Happy path: block until every worker exits; returns one message
    /// per worker that failed (empty = all clean).
    pub fn reap(&mut self) -> Vec<String> {
        let mut failures = Vec::new();
        for (rank, mut child) in self.children.drain(..) {
            match child.wait() {
                Ok(status) if status.success() => {}
                Ok(status) => failures.push(format!("rank {rank} exited with {status}")),
                Err(e) => failures.push(format!("rank {rank} not reaped: {e}")),
            }
        }
        failures
    }

    /// Root-error path: poll for self-exits for up to `grace` (their
    /// sockets just died, so healthy workers exit promptly and their
    /// statuses are worth reporting), then kill and reap the rest.
    /// Never blocks past `grace`; always leaves zero live workers.
    pub fn shutdown(&mut self, grace: std::time::Duration) -> Vec<String> {
        let deadline = std::time::Instant::now() + grace;
        let mut notes = Vec::new();
        let mut rest = std::mem::take(&mut self.children);
        loop {
            rest.retain_mut(|(rank, child)| match child.try_wait() {
                Ok(Some(status)) if status.success() => false,
                Ok(Some(status)) => {
                    notes.push(format!("rank {rank} exited with {status}"));
                    false
                }
                Ok(None) => true,
                Err(e) => {
                    notes.push(format!("rank {rank} not reaped: {e}"));
                    false
                }
            });
            if rest.is_empty() || std::time::Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        for (rank, mut child) in rest {
            let _ = child.kill();
            let _ = child.wait();
            notes.push(format!("rank {rank} killed after the root failed"));
        }
        notes
    }
}

impl Drop for WorkerChildren {
    fn drop(&mut self) {
        for (_, child) in self.children.iter_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
        self.children.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_tracks_every_field() {
        let base = DistSpec::default();
        let fp = base.fingerprint();
        assert_eq!(fp, base.clone().fingerprint(), "deterministic");
        let variants = [
            DistSpec { family: "adam".into(), ..base.clone() },
            DistSpec { d: base.d + 1, ..base.clone() },
            DistSpec { steps: base.steps + 1, ..base.clone() },
            DistSpec { world: base.world + 1, ..base.clone() },
            DistSpec { seed: base.seed + 1, ..base.clone() },
            DistSpec { lr: base.lr * 2.0, ..base.clone() },
            DistSpec { kappa: base.kappa * 2.0, ..base.clone() },
            DistSpec { sigma: base.sigma * 2.0, ..base.clone() },
            DistSpec { init: base.init + 0.5, ..base.clone() },
            DistSpec { topology: Topology::Tree { group: 2 }, ..base.clone() },
        ];
        for v in variants {
            assert_ne!(v.fingerprint(), fp, "{v:?} must change the fingerprint");
        }
        // A degenerate tree (group ≥ world) *is* the star schedule, so
        // either spelling must produce the same handshake token.
        let degenerate = DistSpec { topology: Topology::Tree { group: 9 }, ..base.clone() };
        assert_eq!(degenerate.fingerprint(), fp, "tree9 at world 4 is the star");
    }

    #[test]
    fn every_family_builds_for_both_shapes() {
        for family in FAMILIES {
            let spec = DistSpec { family: family.to_string(), d: 32, ..DistSpec::default() };
            let local = spec.build_optimizer(4).unwrap_or_else(|| panic!("{family}"));
            assert_eq!(local.n_workers(), 4, "{family}");
            assert_eq!(local.dim(), 32, "{family}");
            let rank = spec.build_optimizer(1).unwrap_or_else(|| panic!("{family}"));
            assert_eq!(rank.n_workers(), 1, "{family}");
            // only 0/1 Adam's replicas diverge between syncs
            assert_eq!(local.shared_state(), !family.starts_with("01adam"), "{family}");
        }
        assert!(DistSpec { family: "nope".into(), ..DistSpec::default() }
            .build_optimizer(2)
            .is_none());
    }

    #[test]
    fn world_one_inproc_run_matches_local_sequential() {
        // The degenerate group: one rank, no frames — still must match
        // the single-worker in-process run bit for bit.
        for family in ["adam", "01adam"] {
            let spec = DistSpec {
                family: family.to_string(),
                d: 130,
                steps: 8,
                world: 1,
                ..DistSpec::default()
            };
            let dist = launch_inproc(&spec).unwrap();
            let local = run_local(&spec, ExecMode::Sequential);
            check_parity(&dist[0], &local).unwrap_or_else(|e| panic!("{family}: {e}"));
        }
    }
}
