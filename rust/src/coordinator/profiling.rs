//! Fig-1 moment profiler: tracks ‖v_t − v_{t−1}‖, ‖v_local − v_global‖
//! and the same two metrics for the momentum, during an original-Adam
//! run — the paper's motivation study ("the change of variance over
//! steps is generally smooth"; "the difference between local and global
//! optimizer states remains constant").

use super::trainer::StepObserver;
use crate::optim::{DistOptimizer, Hyper, StepInfo};

pub struct MomentProfiler {
    hyper: Hyper,
    /// Worker-0's *local* moments (what Adam would track if it only saw
    /// worker-0's gradient — the v_t^{(0)} / m_t^{(0)} of Figure 1).
    m_local: Vec<f32>,
    v_local: Vec<f32>,
    prev_m: Vec<f32>,
    prev_v: Vec<f32>,
    /// Record every `every` steps.
    every: u64,
    started: bool,
}

impl MomentProfiler {
    pub fn new(d: usize, hyper: Hyper, every: u64) -> Self {
        MomentProfiler {
            hyper,
            m_local: vec![0.0; d],
            v_local: vec![0.0; d],
            prev_m: vec![0.0; d],
            prev_v: vec![0.0; d],
            every: every.max(1),
            started: false,
        }
    }
}

impl StepObserver for MomentProfiler {
    fn observe(
        &mut self,
        t: u64,
        opt: &dyn DistOptimizer,
        grads: &[Vec<f32>],
        _info: &StepInfo,
    ) -> Option<Vec<(String, f64)>> {
        let (m, v) = (opt.momentum()?, opt.variance()?);

        // Advance worker-0's local moments with its own gradient.
        let g0 = &grads[0];
        let (b1, b2) = (self.hyper.beta1, self.hyper.beta2);
        for i in 0..g0.len() {
            self.m_local[i] = b1 * self.m_local[i] + (1.0 - b1) * g0[i];
            self.v_local[i] = b2 * self.v_local[i] + (1.0 - b2) * g0[i] * g0[i];
        }

        let row = if t % self.every == 0 && self.started {
            Some(vec![
                ("t".to_string(), t as f64),
                ("v_step_diff".to_string(), crate::tensor::dist2(v, &self.prev_v)),
                ("v_local_global".to_string(), crate::tensor::dist2(&self.v_local, v)),
                ("m_step_diff".to_string(), crate::tensor::dist2(m, &self.prev_m)),
                ("m_local_global".to_string(), crate::tensor::dist2(&self.m_local, m)),
            ])
        } else {
            None
        };

        self.prev_m.copy_from_slice(m);
        self.prev_v.copy_from_slice(v);
        self.started = true;
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::{Trainer, TrainerConfig};
    use crate::grad::synthetic::NoisyQuadratic;
    use crate::optim::{Adam, ConstLr};

    #[test]
    fn profiler_emits_fig1_metrics() {
        let d = 32;
        let mut src = NoisyQuadratic::new(d, 5.0, 0.1, 1);
        let mut opt = Adam::new(vec![1.0; d], 4, Hyper::default(), Box::new(ConstLr(0.01)));
        let mut prof = MomentProfiler::new(d, Hyper::default(), 2);
        let cfg = TrainerConfig { steps: 40, ..Default::default() };
        let res = Trainer::run(&mut src, &mut opt, &cfg, &mut prof);
        assert!(res.observer_rows.len() >= 15);
        for row in &res.observer_rows {
            let names: Vec<&str> = row.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(
                names,
                ["t", "v_step_diff", "v_local_global", "m_step_diff", "m_local_global"]
            );
            // all finite and non-negative
            assert!(row.iter().all(|(_, v)| v.is_finite() && *v >= 0.0));
        }
        // Figure-1 shape: local-vs-global momentum gap stays bounded
        // away from zero (workers see different noise)…
        let last = res.observer_rows.last().unwrap();
        assert!(last[4].1 > 0.0);
    }

    #[test]
    fn variance_step_diff_shrinks_over_time() {
        // Figure 1(a): ‖v_t − v_{t−1}‖ decays as v converges to the
        // stationary second moment.
        let d = 64;
        let mut src = NoisyQuadratic::new(d, 2.0, 0.05, 2);
        let mut opt = Adam::new(vec![1.0; d], 2, Hyper::default(), Box::new(ConstLr(0.005)));
        let mut prof = MomentProfiler::new(d, Hyper::default(), 1);
        let cfg = TrainerConfig { steps: 300, ..Default::default() };
        let res = Trainer::run(&mut src, &mut opt, &cfg, &mut prof);
        let diffs: Vec<f64> = res.observer_rows.iter().map(|r| r[1].1).collect();
        let early: f64 = diffs[5..25].iter().sum::<f64>() / 20.0;
        let late: f64 = diffs[diffs.len() - 20..].iter().sum::<f64>() / 20.0;
        assert!(late < early, "early {early} late {late}");
    }
}
