//! Persistent worker pool behind [`super::engine::Engine`].
//!
//! PR 2 left one exemption to the zero-allocation hot-path invariant:
//! every parallel region spawned fresh scoped threads (µs-scale fixed
//! cost and a handful of OS allocations each, thousands of times per
//! training run — the dominant overhead on short regions). This module
//! replaces the per-region spawn with threads created once and parked
//! on condvars; each region becomes a **publish–work–barrier** cycle
//! that performs no heap allocation in steady state:
//!
//! * **publish** — the coordinator carves its region into per-thread
//!   blocks (stack-allocated descriptors, see `engine::run_split`),
//!   stores one type-erased [`Task`] pointer into each participating
//!   worker's **own slot** (its private mutex + condvar), bumps that
//!   slot's epoch and notifies *that worker only*;
//! * **work** — each notified worker takes the task in its slot, runs
//!   it, and decrements the region's pending count;
//! * **barrier** — the coordinator runs its own share of the region,
//!   then blocks on the done condvar until pending reaches zero. Only
//!   after that do the borrows smuggled through the task pointers
//!   expire, so a region has exactly the lifetime discipline of the
//!   scoped-thread version it replaces: every parallel region is still
//!   a barrier.
//!
//! **Per-slot parking (ISSUE 4).** The PR 3 pool kept one shared
//! condvar and `notify_all`-ed the whole pool per region, so a 64-wide
//! pool running a 2-block region woke 62 workers just so they could
//! take `None` and re-park — pure wakeup churn on wide pools running
//! small regions (the common shape once lane chunking keeps regions
//! narrow). Each worker now parks on its own condvar and is only ever
//! notified when a task was published into its slot; idle workers
//! sleep through the region entirely. Each slot counts its condvar
//! wake-ups ([`Pool::wake_count`]) so the property is testable, not
//! just intended (`idle_workers_sleep_through_small_regions`).
//!
//! Panic contract: a panicking task marks the region but the barrier
//! still completes (no worker may keep running into a freed stack
//! frame), and the coordinator re-raises *after* the barrier. Tasks
//! run outside every pool mutex, so a panic poisons nothing and the
//! pool stays fully usable — `#[should_panic]` tests and the CLI's
//! error paths can keep driving the same engine afterwards.
//!
//! **Coordinator-built, region-shared data (ISSUE 5).** Per-round
//! derived state that every block needs — e.g. the EF server leg's
//! 2^n-entry pattern table — is built on the coordinator *between*
//! regions and captured read-only (`&T` through the visitor's `F:
//! Sync`) by the blocks of the next region. The publish–work–barrier
//! cycle makes this sound with no further synchronization: the build
//! happens-before publish, and the barrier keeps the borrow alive
//! until the last worker finished. Mutable per-block data, by
//! contrast, always rides the region's `Split` bundle (one disjoint
//! carve per block — e.g. the table sweep's per-chunk pattern
//! indices).

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

/// Hard cap on the engine pool width. A region's block descriptors
/// live in a fixed-size stack array (no per-region heap), so the width
/// must be bounded; 64 comfortably exceeds any host this simulator
/// targets. `Engine::new` clamps wider `ExecMode::Threaded(n)` here.
pub const MAX_THREADS: usize = 64;

/// A type-erased block of region work: `run(data)` reconstructs the
/// typed block descriptor on the worker and executes it.
///
/// Safety contract (upheld by `Engine::run_split`): `data` stays valid
/// and is touched by no other thread from publish until the region
/// barrier completes, and `run` is the monomorphized runner matching
/// `data`'s concrete type. The payload a task smuggles across threads
/// is `Send` by construction (engine blocks are `S: Split + Send`
/// parts plus an `&F where F: Sync` visitor).
#[derive(Clone, Copy)]
pub(crate) struct Task {
    data: *mut (),
    run: unsafe fn(*mut ()),
}

// SAFETY: a Task is a raw-pointer + fn-pointer bundle; the contract
// above pins `data` valid and untouched by any other thread for the
// region, which is exactly what makes the cross-thread move sound.
unsafe impl Send for Task {}

impl Task {
    /// Safety: the caller promises the [`Task`] contract above.
    pub(crate) unsafe fn new(data: *mut (), run: unsafe fn(*mut ())) -> Task {
        Task { data, run }
    }

    /// Placeholder for the fixed-size publish array; never executed.
    pub(crate) const fn noop() -> Task {
        // SAFETY: never executed (placeholder slot); touches nothing.
        unsafe fn nop(_: *mut ()) {}
        Task { data: std::ptr::null_mut(), run: nop }
    }
}

/// One worker's private parking spot: publishing a task locks only
/// this mutex and notifies only this condvar.
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
    /// Condvar wake-ups this worker has experienced (returns from
    /// `cv.wait`, spurious included). The per-slot-parking win is that
    /// idle workers' counters stay ~0 while small regions run.
    wakes: AtomicU64,
}

struct SlotState {
    /// Bumped once per task published into this slot.
    epoch: u64,
    /// `Some` between publish and the worker's take.
    task: Option<Task>,
    shutdown: bool,
}

/// Region-completion state shared by the whole pool (the barrier).
struct Done {
    /// Workers still running the current region.
    pending: usize,
    /// Some task of the current region panicked.
    panicked: bool,
}

struct Shared {
    slots: Vec<Slot>,
    done: Mutex<Done>,
    done_cv: Condvar,
}

/// Lock, shrugging off poison: tasks run *outside* every pool mutex,
/// so a poisoned lock only means some thread panicked between state
/// transitions that are each individually complete — the state is
/// always consistent and the pool must keep operating (e.g. through
/// `#[should_panic]` tests).
fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The persistent pool: `workers` parked threads plus the calling
/// thread as the implicit extra lane (an `ExecMode::Threaded(n)`
/// engine builds a pool of `n − 1`).
pub(crate) struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("workers", &self.handles.len()).finish()
    }
}

impl Pool {
    /// Spawn the pool. The only heap allocations the pool ever
    /// performs happen here (thread stacks, slots and bookkeeping are
    /// paid once, at construction — not per region).
    pub(crate) fn new(workers: usize) -> Pool {
        let workers = workers.min(MAX_THREADS);
        let shared = Arc::new(Shared {
            slots: (0..workers)
                .map(|_| Slot {
                    state: Mutex::new(SlotState { epoch: 0, task: None, shutdown: false }),
                    cv: Condvar::new(),
                    wakes: AtomicU64::new(0),
                })
                .collect(),
            done: Mutex::new(Done { pending: 0, panicked: false }),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("zo-engine-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn engine pool worker")
            })
            .collect();
        Pool { shared, handles }
    }

    pub(crate) fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Condvar wake-ups worker `i` has experienced since construction.
    /// With per-slot parking this stays ~0 for workers no region ever
    /// publishes a task to.
    #[cfg_attr(not(test), allow(dead_code))]
    pub(crate) fn wake_count(&self, i: usize) -> u64 {
        self.shared.slots[i].wakes.load(Ordering::Relaxed)
    }

    /// Run one parallel region: `tasks[i]` is handed to pool worker
    /// `i` while `own` (the coordinator's share) runs on the calling
    /// thread. Returns only after every task finished — the barrier.
    /// Panics in any task (or in `own`) are re-raised here *after* the
    /// barrier, so no task can outlive the borrows it was given.
    ///
    /// Safety: every [`Task`] must uphold the [`Task`] contract for
    /// the duration of this call.
    pub(crate) unsafe fn run_region(&self, tasks: &[Task], own: impl FnOnce()) {
        assert!(
            tasks.len() <= self.handles.len(),
            "region published {} blocks onto a pool of {} workers",
            tasks.len(),
            self.handles.len()
        );
        if tasks.is_empty() {
            own();
            return;
        }
        // Arm the barrier *before* the first notify so no worker can
        // drive pending below zero, then publish each task into its
        // worker's own slot — only the k participating workers are
        // locked and woken; the rest of the pool sleeps on.
        {
            let mut done = lock(&self.shared.done);
            assert_eq!(done.pending, 0, "engine parallel regions must not nest");
            done.pending = tasks.len();
            done.panicked = false;
        }
        for (slot, t) in self.shared.slots.iter().zip(tasks) {
            let mut st = lock(&slot.state);
            debug_assert!(st.task.is_none(), "slot still holds an unconsumed task");
            st.task = Some(*t);
            st.epoch = st.epoch.wrapping_add(1);
            slot.cv.notify_one();
        }
        crate::obs::mark_n(crate::obs::PhaseId::RegionPublish, tasks.len() as u64);
        // The coordinator is never idle while the pool runs — and if
        // its own share panics, the barrier must still complete first:
        // workers hold pointers into this very stack frame.
        let own_result = panic::catch_unwind(AssertUnwindSafe(own));
        let worker_panicked = {
            let mut done = lock(&self.shared.done);
            while done.pending != 0 {
                done = self.shared.done_cv.wait(done).unwrap_or_else(PoisonError::into_inner);
            }
            done.panicked
        };
        crate::obs::mark(crate::obs::PhaseId::RegionBarrier);
        if let Err(p) = own_result {
            panic::resume_unwind(p);
        }
        if worker_panicked {
            panic!("engine pool worker panicked during a parallel region");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for slot in &self.shared.slots {
            let mut st = lock(&slot.state);
            st.shutdown = true;
            slot.cv.notify_one();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, idx: usize) {
    let slot = &shared.slots[idx];
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = lock(&slot.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.task.take();
                }
                st = slot.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                slot.wakes.fetch_add(1, Ordering::Relaxed);
            }
        };
        // An epoch bump without a task cannot happen (epochs only move
        // when a task is published into this very slot), but stay
        // defensive: the barrier accounting below must not run twice.
        let Some(task) = task else { continue };
        // SAFETY: the publisher (run_region) keeps task.data valid and
        // unaliased until the barrier below releases the region.
        let ok = panic::catch_unwind(AssertUnwindSafe(|| unsafe { (task.run)(task.data) })).is_ok();
        let mut done = lock(&shared.done);
        if !ok {
            done.panicked = true;
        }
        done.pending -= 1;
        if done.pending == 0 {
            shared.done_cv.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Minimal typed payload for direct pool tests (the engine's real
    /// payloads are `Block` descriptors in `engine.rs`).
    struct Probe<'a> {
        hits: &'a AtomicUsize,
        boom: bool,
    }

    // SAFETY: callers pass a pointer to a live `Option<Probe>` no other
    // thread touches while the region runs.
    unsafe fn run_probe(p: *mut ()) {
        let probe = &mut *(p as *mut Option<Probe<'_>>);
        let probe = probe.take().expect("probe ran twice");
        probe.hits.fetch_add(1, Ordering::SeqCst);
        if probe.boom {
            panic!("probe boom");
        }
    }

    fn publish<'a>(slots: &mut [Option<Probe<'a>>]) -> Vec<Task> {
        slots
            .iter_mut()
            // SAFETY: each slot outlives the region its task runs in,
            // and run_probe matches the `Option<Probe>` payload type.
            .map(|s| unsafe { Task::new(s as *mut Option<Probe<'a>> as *mut (), run_probe) })
            .collect()
    }

    #[test]
    fn regions_run_every_task_and_the_own_share() {
        let pool = Pool::new(3);
        let hits = AtomicUsize::new(0);
        for round in 0..50 {
            hits.store(0, Ordering::SeqCst);
            let k = round % 4; // 0..=3 published tasks per region
            let mut slots: Vec<Option<Probe<'_>>> =
                (0..k).map(|_| Some(Probe { hits: &hits, boom: false })).collect();
            let tasks = publish(&mut slots);
            // SAFETY: `slots` stays alive and untouched until the
            // region barrier returns.
            unsafe {
                pool.run_region(&tasks, || {
                    hits.fetch_add(100, Ordering::SeqCst);
                });
            }
            assert_eq!(hits.load(Ordering::SeqCst), 100 + k, "round {round}");
            assert!(slots.iter().all(|s| s.is_none()), "round {round}: task not consumed");
        }
    }

    #[test]
    fn idle_workers_sleep_through_small_regions() {
        // The ISSUE 4 satellite: a wide pool running single-block
        // regions must not wake its idle workers. Worker 0 gets every
        // task; workers 1..7 are never notified, so their wake
        // counters stay at (essentially) zero — under the old shared
        // `notify_all` design every region woke all 8, i.e. this sum
        // would be ~7 × regions.
        let pool = Pool::new(8);
        let hits = AtomicUsize::new(0);
        let regions = 200u64;
        for _ in 0..regions {
            let mut slots = vec![Some(Probe { hits: &hits, boom: false })];
            let tasks = publish(&mut slots);
            // SAFETY: `slots` outlives the region barrier.
            unsafe { pool.run_region(&tasks, || {}) };
        }
        assert_eq!(hits.load(Ordering::SeqCst), regions as usize);
        assert!(pool.wake_count(0) >= 1, "the busy worker must actually park and wake");
        let idle: u64 = (1..8).map(|i| pool.wake_count(i)).sum();
        // Strictly 0 modulo (OS-permitted, practically nonexistent)
        // spurious wakeups; any real notify_all regression lands at
        // ~7 × regions = 1400.
        assert!(
            idle < regions / 2,
            "idle workers woke {idle} times across {regions} single-block regions"
        );
    }

    #[test]
    fn worker_panic_reraises_after_the_barrier_and_pool_survives() {
        let pool = Pool::new(2);
        let hits = AtomicUsize::new(0);
        let mut slots = vec![
            Some(Probe { hits: &hits, boom: true }),
            Some(Probe { hits: &hits, boom: false }),
        ];
        let tasks = publish(&mut slots);
        // SAFETY: `slots` outlives the region barrier (a task panic is
        // re-raised only after every task completed).
        let r = panic::catch_unwind(AssertUnwindSafe(|| unsafe {
            pool.run_region(&tasks, || {});
        }));
        assert!(r.is_err(), "worker panic must propagate");
        // both tasks ran to the barrier despite the panic
        assert_eq!(hits.load(Ordering::SeqCst), 2);

        // and the pool still works
        let mut slots = vec![Some(Probe { hits: &hits, boom: false })];
        let tasks = publish(&mut slots);
        // SAFETY: `slots` outlives the region barrier.
        unsafe { pool.run_region(&tasks, || {}) };
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn empty_pool_and_empty_region_are_fine() {
        let pool = Pool::new(0);
        assert_eq!(pool.workers(), 0);
        let mut ran = false;
        // SAFETY: the region publishes no tasks at all.
        unsafe { pool.run_region(&[], || ran = true) };
        assert!(ran);
        // drop joins nothing
    }

    #[test]
    fn drop_rebuild_cycles_are_clean() {
        for _ in 0..5 {
            let pool = Pool::new(4);
            let hits = AtomicUsize::new(0);
            let mut slots: Vec<Option<Probe<'_>>> =
                (0..4).map(|_| Some(Probe { hits: &hits, boom: false })).collect();
            let tasks = publish(&mut slots);
            // SAFETY: `slots` outlives the region barrier.
            unsafe { pool.run_region(&tasks, || {}) };
            assert_eq!(hits.load(Ordering::SeqCst), 4);
            drop(pool);
        }
        // a pool dropped without ever running a region
        drop(Pool::new(3));
    }
}
