//! Persistent worker pool behind [`super::engine::Engine`].
//!
//! PR 2 left one exemption to the zero-allocation hot-path invariant:
//! every parallel region spawned fresh scoped threads (µs-scale fixed
//! cost and a handful of OS allocations each, thousands of times per
//! training run — the dominant overhead on short regions). This module
//! replaces the per-region spawn with threads created once and parked
//! on a condvar; each region becomes a **publish–work–barrier** cycle
//! that performs no heap allocation in steady state:
//!
//! * **publish** — the coordinator carves its region into per-thread
//!   blocks (stack-allocated descriptors, see `engine::run_split`),
//!   stores one type-erased [`Task`] pointer per worker slot under the
//!   pool mutex, bumps the region epoch and notifies the pool;
//! * **work** — each woken worker takes the task in its slot (if any),
//!   runs it, and decrements the epoch's pending count;
//! * **barrier** — the coordinator runs its own share of the region,
//!   then blocks on the done condvar until pending reaches zero. Only
//!   after that do the borrows smuggled through the task pointers
//!   expire, so a region has exactly the lifetime discipline of the
//!   scoped-thread version it replaces: every parallel region is still
//!   a barrier.
//!
//! Panic contract: a panicking task marks the epoch but the barrier
//! still completes (no worker may keep running into a freed stack
//! frame), and the coordinator re-raises *after* the barrier. Tasks
//! run outside the pool mutex, so a panic poisons nothing and the pool
//! stays fully usable — `#[should_panic]` tests and the CLI's error
//! paths can keep driving the same engine afterwards.

use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

/// Hard cap on the engine pool width. A region's block descriptors
/// live in a fixed-size stack array (no per-region heap), so the width
/// must be bounded; 64 comfortably exceeds any host this simulator
/// targets. `Engine::new` clamps wider `ExecMode::Threaded(n)` here.
pub const MAX_THREADS: usize = 64;

/// A type-erased block of region work: `run(data)` reconstructs the
/// typed block descriptor on the worker and executes it.
///
/// Safety contract (upheld by `Engine::run_split`): `data` stays valid
/// and is touched by no other thread from publish until the region
/// barrier completes, and `run` is the monomorphized runner matching
/// `data`'s concrete type. The payload a task smuggles across threads
/// is `Send` by construction (engine blocks are `S: Split + Send`
/// parts plus an `&F where F: Sync` visitor).
#[derive(Clone, Copy)]
pub(crate) struct Task {
    data: *mut (),
    run: unsafe fn(*mut ()),
}

unsafe impl Send for Task {}

impl Task {
    /// See the safety contract on [`Task`].
    pub(crate) unsafe fn new(data: *mut (), run: unsafe fn(*mut ())) -> Task {
        Task { data, run }
    }

    /// Placeholder for the fixed-size publish array; never executed.
    pub(crate) const fn noop() -> Task {
        unsafe fn nop(_: *mut ()) {}
        Task { data: std::ptr::null_mut(), run: nop }
    }
}

struct State {
    /// Region counter; a bump publishes the tasks of a new region.
    epoch: u64,
    /// One slot per worker; `None` = idle this region.
    tasks: [Option<Task>; MAX_THREADS],
    /// Workers still running the current region.
    pending: usize,
    /// Some task of the current region panicked.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between regions.
    work: Condvar,
    /// The coordinator waits here for `pending == 0` — the barrier.
    done: Condvar,
}

/// Lock, shrugging off poison: tasks run *outside* the mutex, so a
/// poisoned lock only means some thread panicked between state
/// transitions that are each individually complete — the state is
/// always consistent and the pool must keep operating (e.g. through
/// `#[should_panic]` tests).
fn lock(shared: &Shared) -> MutexGuard<'_, State> {
    shared.state.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The persistent pool: `workers` parked threads plus the calling
/// thread as the implicit extra lane (an `ExecMode::Threaded(n)`
/// engine builds a pool of `n − 1`).
pub(crate) struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("workers", &self.handles.len()).finish()
    }
}

impl Pool {
    /// Spawn the pool. The only heap allocations the pool ever
    /// performs happen here (thread stacks and bookkeeping are paid
    /// once, at construction — not per region).
    pub(crate) fn new(workers: usize) -> Pool {
        let workers = workers.min(MAX_THREADS);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                tasks: [None; MAX_THREADS],
                pending: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("zo-engine-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn engine pool worker")
            })
            .collect();
        Pool { shared, handles }
    }

    pub(crate) fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run one parallel region: `tasks[i]` is handed to pool worker
    /// `i` while `own` (the coordinator's share) runs on the calling
    /// thread. Returns only after every task finished — the barrier.
    /// Panics in any task (or in `own`) are re-raised here *after* the
    /// barrier, so no task can outlive the borrows it was given.
    ///
    /// Safety: every [`Task`] must uphold the [`Task`] contract for
    /// the duration of this call.
    pub(crate) unsafe fn run_region(&self, tasks: &[Task], own: impl FnOnce()) {
        assert!(
            tasks.len() <= self.handles.len(),
            "region published {} blocks onto a pool of {} workers",
            tasks.len(),
            self.handles.len()
        );
        if tasks.is_empty() {
            own();
            return;
        }
        {
            let mut st = lock(&self.shared);
            assert_eq!(st.pending, 0, "engine parallel regions must not nest");
            for (slot, t) in st.tasks.iter_mut().zip(tasks) {
                *slot = Some(*t);
            }
            st.pending = tasks.len();
            st.panicked = false;
            st.epoch = st.epoch.wrapping_add(1);
            self.shared.work.notify_all();
        }
        // The coordinator is never idle while the pool runs — and if
        // its own share panics, the barrier must still complete first:
        // workers hold pointers into this very stack frame.
        let own_result = panic::catch_unwind(AssertUnwindSafe(own));
        let worker_panicked = {
            let mut st = lock(&self.shared);
            while st.pending != 0 {
                st = self.shared.done.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            st.panicked
        };
        if let Err(p) = own_result {
            panic::resume_unwind(p);
        }
        if worker_panicked {
            panic!("engine pool worker panicked during a parallel region");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared);
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, idx: usize) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = lock(shared);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.tasks[idx].take();
                }
                st = shared.work.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        // `None`: this worker is idle for the current region (fewer
        // blocks than workers) — go straight back to the condvar.
        let Some(task) = task else { continue };
        let ok = panic::catch_unwind(AssertUnwindSafe(|| unsafe { (task.run)(task.data) })).is_ok();
        let mut st = lock(shared);
        if !ok {
            st.panicked = true;
        }
        st.pending -= 1;
        if st.pending == 0 {
            shared.done.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Minimal typed payload for direct pool tests (the engine's real
    /// payloads are `Block` descriptors in `engine.rs`).
    struct Probe<'a> {
        hits: &'a AtomicUsize,
        boom: bool,
    }

    unsafe fn run_probe(p: *mut ()) {
        let probe = &mut *(p as *mut Option<Probe<'_>>);
        let probe = probe.take().expect("probe ran twice");
        probe.hits.fetch_add(1, Ordering::SeqCst);
        if probe.boom {
            panic!("probe boom");
        }
    }

    fn publish<'a>(slots: &mut [Option<Probe<'a>>]) -> Vec<Task> {
        slots
            .iter_mut()
            .map(|s| unsafe { Task::new(s as *mut Option<Probe<'a>> as *mut (), run_probe) })
            .collect()
    }

    #[test]
    fn regions_run_every_task_and_the_own_share() {
        let pool = Pool::new(3);
        let hits = AtomicUsize::new(0);
        for round in 0..50 {
            hits.store(0, Ordering::SeqCst);
            let k = round % 4; // 0..=3 published tasks per region
            let mut slots: Vec<Option<Probe<'_>>> =
                (0..k).map(|_| Some(Probe { hits: &hits, boom: false })).collect();
            let tasks = publish(&mut slots);
            unsafe {
                pool.run_region(&tasks, || {
                    hits.fetch_add(100, Ordering::SeqCst);
                });
            }
            assert_eq!(hits.load(Ordering::SeqCst), 100 + k, "round {round}");
            assert!(slots.iter().all(|s| s.is_none()), "round {round}: task not consumed");
        }
    }

    #[test]
    fn worker_panic_reraises_after_the_barrier_and_pool_survives() {
        let pool = Pool::new(2);
        let hits = AtomicUsize::new(0);
        let mut slots = vec![
            Some(Probe { hits: &hits, boom: true }),
            Some(Probe { hits: &hits, boom: false }),
        ];
        let tasks = publish(&mut slots);
        let r = panic::catch_unwind(AssertUnwindSafe(|| unsafe {
            pool.run_region(&tasks, || {});
        }));
        assert!(r.is_err(), "worker panic must propagate");
        // both tasks ran to the barrier despite the panic
        assert_eq!(hits.load(Ordering::SeqCst), 2);

        // and the pool still works
        let mut slots = vec![Some(Probe { hits: &hits, boom: false })];
        let tasks = publish(&mut slots);
        unsafe { pool.run_region(&tasks, || {}) };
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn empty_pool_and_empty_region_are_fine() {
        let pool = Pool::new(0);
        assert_eq!(pool.workers(), 0);
        let mut ran = false;
        unsafe { pool.run_region(&[], || ran = true) };
        assert!(ran);
        // drop joins nothing
    }

    #[test]
    fn drop_rebuild_cycles_are_clean() {
        for _ in 0..5 {
            let pool = Pool::new(4);
            let hits = AtomicUsize::new(0);
            let mut slots: Vec<Option<Probe<'_>>> =
                (0..4).map(|_| Some(Probe { hits: &hits, boom: false })).collect();
            let tasks = publish(&mut slots);
            unsafe { pool.run_region(&tasks, || {}) };
            assert_eq!(hits.load(Ordering::SeqCst), 4);
            drop(pool);
        }
        // a pool dropped without ever running a region
        drop(Pool::new(3));
    }
}
