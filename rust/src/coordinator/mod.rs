//! The L3 coordinator: training-loop orchestration, metrics, profiling.

pub mod metrics;
pub mod profiling;
pub mod trainer;

pub use metrics::{MetricLog, StepRecord};
pub use profiling::MomentProfiler;
pub use trainer::{NoObserver, RunResult, StepObserver, Trainer, TrainerConfig};
