//! The L3 coordinator: training-loop orchestration (in-process and
//! rank-distributed), the deterministic parallel execution engine,
//! metrics, profiling.

pub mod chaos;
pub mod distributed;
pub mod engine;
pub mod metrics;
mod pool;
pub mod profiling;
pub mod trainer;

pub use chaos::{run_cell, CellOutcome, CellReport, ChaosOpts};
pub use distributed::{
    check_parity, launch_inproc, launch_inproc_opts, run_local, run_rank, run_rank_opts, DistSpec,
    RankOpts, RankResult, WorkerChildren,
};
pub use engine::{Engine, ExecMode, MAX_POOL_THREADS};
pub use metrics::{MetricLog, StepRecord};
pub use profiling::MomentProfiler;
pub use trainer::{NoObserver, RunResult, StepObserver, Trainer, TrainerConfig};
