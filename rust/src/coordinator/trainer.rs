//! The training-loop orchestrator: drives n workers against a gradient
//! source and a distributed optimizer, maintains the simulated cluster
//! clock and the volume ledger, and logs metrics.
//!
//! This is the leader process of the paper's system: every figure's
//! training run goes through [`Trainer::run`].

use crate::comm::network::Fabric;
use crate::comm::volume::VolumeLedger;
use crate::comm::{ReduceBackend, Topology};
use crate::grad::GradientSource;
use crate::optim::{DistOptimizer, StepInfo};
use crate::runtime::checkpoint::{
    read_shard, write_shard, CheckpointCfg, CheckpointError, StateReader, StateWriter,
};
use crate::runtime::manifest::RunManifest;

use super::engine::{Engine, ExecMode};
use super::metrics::{MetricLog, StepRecord};

/// Trainer configuration (independent of model/optimizer choice).
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub steps: u64,
    /// Log a metric record every `log_every` steps (last step always).
    pub log_every: u64,
    /// Evaluate held-out loss every `eval_every` steps (0 = never).
    pub eval_every: u64,
    /// Simulated fabric for the cluster clock (None = no timing).
    pub fabric: Option<Fabric>,
    /// Simulated cluster size (for the clock; may exceed the number of
    /// *materialized* workers when studying wall-clock at paper scale).
    pub sim_gpus: usize,
    /// Simulated per-step compute time in ms (0 = exclude compute).
    pub compute_ms: f64,
    /// Execution engine for materialized workers. `Threaded(n)` runs
    /// the gradient and per-worker optimizer phases on n pool threads
    /// with bitwise-identical results (see `coordinator::engine`). The
    /// trainer builds one engine per run: its persistent pool is
    /// spawned once up front and every step's parallel regions reuse
    /// it (publish–work–barrier, no per-region spawn or allocation).
    pub exec: ExecMode,
    /// Reduction schedule shape: the star every optimizer defaults to,
    /// or the two-level tree (leaders combine their group, the root
    /// combines leaders). Tree runs are their own trajectory — bitwise
    /// equal to the transport deployment of the same topology, not to
    /// the star (see `comm::topology`).
    pub topology: Topology,
    /// Print progress lines.
    pub verbose: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            steps: 100,
            log_every: 10,
            eval_every: 0,
            fabric: None,
            sim_gpus: 0,
            compute_ms: 0.0,
            exec: ExecMode::Sequential,
            topology: Topology::Star,
            verbose: false,
        }
    }
}

/// Everything a run produced.
pub struct RunResult {
    pub log: MetricLog,
    pub ledger: VolumeLedger,
    /// Total simulated cluster time (s), if a fabric was configured.
    pub sim_total_s: f64,
    /// Wall-clock of the run itself (s).
    pub wall_s: f64,
    /// Mean model across workers at the end.
    pub final_params: Vec<f32>,
    pub final_eval: Option<f32>,
    /// Per-step observer output (Fig-1 profiler etc.), if any.
    pub observer_rows: Vec<Vec<(String, f64)>>,
}

/// Per-step hook (e.g. the Fig-1 moment profiler). Returns named values
/// to record for this step, or None to skip.
pub trait StepObserver {
    fn observe(
        &mut self,
        t: u64,
        opt: &dyn DistOptimizer,
        grads: &[Vec<f32>],
        info: &StepInfo,
    ) -> Option<Vec<(String, f64)>>;
}

/// A no-op observer.
pub struct NoObserver;

impl StepObserver for NoObserver {
    fn observe(
        &mut self,
        _t: u64,
        _opt: &dyn DistOptimizer,
        _grads: &[Vec<f32>],
        _info: &StepInfo,
    ) -> Option<Vec<(String, f64)>> {
        None
    }
}

pub struct Trainer;

impl Trainer {
    /// Run `cfg.steps` of distributed training.
    pub fn run(
        source: &mut dyn GradientSource,
        opt: &mut dyn DistOptimizer,
        cfg: &TrainerConfig,
        observer: &mut dyn StepObserver,
    ) -> RunResult {
        Self::run_inner(source, opt, cfg, observer, None)
            .unwrap_or_else(|e| unreachable!("no checkpoint config, no checkpoint errors: {e}"))
    }

    /// Run with periodic checkpoints and (optionally) resume (ISSUE 10).
    ///
    /// The in-process flow writes a single `rank0.ckpt` shard holding
    /// the whole snapshot — optimizer state (all replicas + EF error
    /// memory), volume ledger, simulated clock, and the metric log —
    /// plus a `manifest.json` (layout `"single"`) binding the shard
    /// digest to the run's spec fingerprint. Resume-at-step-t then
    /// continues the loop at `t` and is bit-for-bit identical to an
    /// uninterrupted run: every per-step input (gradient noise, LR,
    /// schedules) is a pure function of `t` and the restored state.
    ///
    /// Observer rows are deliberately *not* checkpointed: observers are
    /// analysis taps (Fig-1 profiler), not training state, and a resumed
    /// run only reports rows for the steps it actually executed.
    pub fn run_checkpointed(
        source: &mut dyn GradientSource,
        opt: &mut dyn DistOptimizer,
        cfg: &TrainerConfig,
        observer: &mut dyn StepObserver,
        ckpt: &CheckpointCfg,
    ) -> Result<RunResult, CheckpointError> {
        Self::run_inner(source, opt, cfg, observer, Some(ckpt))
    }

    /// Serialize the full in-process run state into one shard body.
    fn save_local(
        opt: &dyn DistOptimizer,
        ledger: &VolumeLedger,
        log: &MetricLog,
        sim_total_ms: f64,
        ck: &CheckpointCfg,
        step: u64,
    ) -> Result<(), CheckpointError> {
        let mut w = StateWriter::new();
        w.put_str("local");
        opt.save_state(&mut w);
        ledger.save_state(&mut w);
        w.put_f64(sim_total_ms);
        w.put_u64(log.records.len() as u64);
        for r in &log.records {
            w.put_u64(r.t);
            w.put_f64(r.loss);
            w.put_f64(r.lr);
            w.put_bool(r.synced);
            w.put_bool(r.var_updated);
            w.put_u64(r.wire_bytes);
            w.put_f64(r.sim_ms);
            w.put_f64(r.sim_total_s);
            w.put_bool(r.eval_loss.is_some());
            w.put_f64(r.eval_loss.unwrap_or(0.0));
        }
        let info = write_shard(&ck.dir, 0, step, w.bytes())?;
        RunManifest::new(step, ck.meta.clone(), "single", vec![info.into()]).write(&ck.dir)
    }

    /// Restore a `save_local` snapshot; returns the step to resume at.
    fn resume_local(
        opt: &mut dyn DistOptimizer,
        ledger: &mut VolumeLedger,
        log: &mut MetricLog,
        sim_total_ms: &mut f64,
        ck: &CheckpointCfg,
    ) -> Result<u64, CheckpointError> {
        let man = RunManifest::load(&ck.dir)?;
        man.check(&ck.meta, "single", 1)?;
        let entry = man.shard(0)?;
        let (step, body) = read_shard(&ck.dir, 0, Some(entry.digest))?;
        if step != man.step {
            return Err(CheckpointError::StepMismatch { manifest: man.step, shard: step });
        }
        let mut r = StateReader::new(&body, &entry.file);
        r.expect_tag("local")?;
        opt.load_state(&mut r)?;
        ledger.load_state(&mut r)?;
        *sim_total_ms = r.take_f64()?;
        let count = r.take_u64()?;
        for _ in 0..count {
            let t = r.take_u64()?;
            let loss = r.take_f64()?;
            let lr = r.take_f64()?;
            let synced = r.take_bool()?;
            let var_updated = r.take_bool()?;
            let wire_bytes = r.take_u64()?;
            let sim_ms = r.take_f64()?;
            let sim_total_s = r.take_f64()?;
            let has_eval = r.take_bool()?;
            let eval = r.take_f64()?;
            log.push(StepRecord {
                t,
                loss,
                lr,
                synced,
                var_updated,
                wire_bytes,
                sim_ms,
                sim_total_s,
                eval_loss: has_eval.then_some(eval),
            });
        }
        r.finish()?;
        Ok(step)
    }

    fn run_inner(
        source: &mut dyn GradientSource,
        opt: &mut dyn DistOptimizer,
        cfg: &TrainerConfig,
        observer: &mut dyn StepObserver,
        ckpt: Option<&CheckpointCfg>,
    ) -> Result<RunResult, CheckpointError> {
        let d = opt.dim();
        assert_eq!(source.dim(), d, "source/optimizer dim mismatch");
        let n = opt.n_workers();
        let sim_gpus = if cfg.sim_gpus > 0 { cfg.sim_gpus } else { n };

        let mut grads: Vec<Vec<f32>> = vec![vec![0.0; d]; n];
        let mut losses: Vec<f32> = vec![0.0; n];
        let mut mean_scratch = vec![0.0f32; d];
        let mut ledger = VolumeLedger::new(d);
        let mut log = MetricLog::new(opt.name());
        let mut observer_rows = Vec::new();
        let mut sim_total_ms = 0.0f64;
        // One engine — and one persistent worker pool — for the whole
        // run; dropped (workers joined) when the run returns.
        let engine = Engine::new(cfg.exec);
        // Normalize once: a tree whose group covers all n workers is
        // the star schedule, and the collectives key off the shape.
        let topology = cfg.topology.normalized(n);
        let wall = crate::util::Stopwatch::start();

        // Resume before the first step: the restored state is exactly
        // what an uninterrupted run held entering step `start_t`.
        let mut start_t = 0u64;
        if let Some(ck) = ckpt {
            if ck.resume {
                start_t =
                    Self::resume_local(opt, &mut ledger, &mut log, &mut sim_total_ms, ck)?;
            }
        }

        for t in start_t..cfg.steps {
            crate::obs::begin(crate::obs::PhaseId::Step);
            // Phase 1: each worker computes its local gradient. With a
            // threaded engine and a thread-shareable source, workers fan
            // out across the pool; losses are still averaged on the
            // coordinator thread in worker order, so both paths produce
            // the same f64 sum bit for bit. No per-step scratch is
            // built: the worker blocks are carved straight off the
            // persistent grads/losses buffers.
            let mut grads_done = false;
            if engine.is_parallel() {
                if let Some(par) = source.parallel() {
                    let opt_ro: &dyn DistOptimizer = &*opt;
                    let per = n.div_ceil(engine.threads()).max(1);
                    engine.run_split(
                        n,
                        per,
                        (&mut grads[..], &mut losses[..]),
                        |_ci, off, (gs, ls)| {
                            for (j, (g, l)) in gs.iter_mut().zip(ls.iter_mut()).enumerate() {
                                let w = off + j;
                                *l = par.grad_at(opt_ro.params(w), w, t, g);
                            }
                        },
                    );
                    grads_done = true;
                }
            }
            if !grads_done {
                for w in 0..n {
                    let params = opt.params(w);
                    losses[w] = source.grad(params, w, t, &mut grads[w]);
                }
            }
            let loss = losses.iter().map(|&l| l as f64).sum::<f64>() / n as f64;

            // Phase 2: the distributed optimizer step (comm included),
            // with the per-worker local phase on the engine and the
            // reductions on the configured topology.
            let info = opt
                .step_comm(t, &grads, &engine, &mut ReduceBackend::Local(topology))
                .unwrap_or_else(|e| unreachable!("in-process reductions are infallible: {e}"));
            ledger.record_step(&info.rounds);

            // Phase 3: simulated cluster clock.
            let mut step_ms = cfg.compute_ms;
            if let Some(fabric) = &cfg.fabric {
                for r in info.rounds.iter() {
                    step_ms += fabric.round_ms(r, d, sim_gpus);
                }
            }
            sim_total_ms += step_ms;

            if let Some(row) = observer.observe(t, &*opt, &grads, &info) {
                observer_rows.push(row);
            }

            // Phase 4: metrics.
            let is_last = t + 1 == cfg.steps;
            if t % cfg.log_every.max(1) == 0 || is_last {
                let eval_loss = if cfg.eval_every > 0
                    && (t % cfg.eval_every == 0 || is_last)
                {
                    opt.mean_params(&mut mean_scratch);
                    source.eval_loss(&mean_scratch).map(|e| e as f64)
                } else {
                    None
                };
                let wire: u64 = info.rounds.iter().map(|r| r.total_per_worker()).sum();
                log.push(StepRecord {
                    t,
                    loss,
                    lr: info.lr,
                    synced: info.synced,
                    var_updated: info.var_updated,
                    wire_bytes: wire,
                    sim_ms: step_ms,
                    sim_total_s: sim_total_ms / 1e3,
                    eval_loss,
                });
                if cfg.verbose {
                    crate::info!(
                        "[{}] t={t} loss={loss:.4} lr={:.2e} sim={:.1}s{}",
                        opt.name(),
                        info.lr,
                        sim_total_ms / 1e3,
                        eval_loss
                            .map(|e| format!(" eval={e:.4}"))
                            .unwrap_or_default()
                    );
                }
            }

            // Phase 5: checkpoint. Cut *after* the step completes, so a
            // shard stamped `t + 1` means "steps 0..=t are done, resume
            // at t + 1" — matching the manifest's `step` semantics.
            if let Some(ck) = ckpt {
                if ck.every > 0 && (t + 1) % ck.every == 0 {
                    Self::save_local(&*opt, &ledger, &log, sim_total_ms, ck, t + 1)?;
                }
            }
            crate::obs::end(crate::obs::PhaseId::Step);
        }

        let mut final_params = vec![0.0f32; d];
        opt.mean_params(&mut final_params);
        let final_eval = source.eval_loss(&final_params);

        Ok(RunResult {
            log,
            ledger,
            sim_total_s: sim_total_ms / 1e3,
            wall_s: wall.elapsed_secs(),
            final_params,
            final_eval,
            observer_rows,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::network::ETHERNET;
    use crate::grad::synthetic::NoisyQuadratic;
    use crate::optim::{Adam, ConstLr, Hyper};

    fn quick_run(steps: u64) -> RunResult {
        let mut src = NoisyQuadratic::new(32, 5.0, 0.05, 1);
        let mut opt = Adam::new(vec![1.0; 32], 4, Hyper::default(), Box::new(ConstLr(0.05)));
        let cfg = TrainerConfig {
            steps,
            log_every: 5,
            eval_every: 10,
            fabric: Some(ETHERNET),
            sim_gpus: 16,
            compute_ms: 10.0,
            exec: ExecMode::Sequential,
            topology: Topology::Star,
            verbose: false,
        };
        Trainer::run(&mut src, &mut opt, &cfg, &mut NoObserver)
    }

    #[test]
    fn threaded_run_is_bitwise_identical() {
        // The tentpole contract, end to end through Trainer::run.
        let run = |exec: ExecMode| {
            let mut src = NoisyQuadratic::new(48, 4.0, 0.1, 9);
            let mut opt =
                Adam::new(vec![1.0; 48], 4, Hyper::default(), Box::new(ConstLr(0.02)));
            let cfg = TrainerConfig {
                steps: 60,
                log_every: 7,
                eval_every: 20,
                fabric: Some(ETHERNET),
                sim_gpus: 16,
                compute_ms: 5.0,
                exec,
                topology: Topology::Star,
                verbose: false,
            };
            Trainer::run(&mut src, &mut opt, &cfg, &mut NoObserver)
        };
        let a = run(ExecMode::Sequential);
        let b = run(ExecMode::Threaded(4));
        assert_eq!(a.final_params.len(), b.final_params.len());
        for (x, y) in a.final_params.iter().zip(&b.final_params) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.sim_total_s.to_bits(), b.sim_total_s.to_bits());
        assert_eq!(a.ledger.bytes_total, b.ledger.bytes_total);
        for (ra, rb) in a.log.records.iter().zip(&b.log.records) {
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "t={}", ra.t);
        }
    }

    #[test]
    fn training_reduces_quadratic_loss() {
        let res = quick_run(200);
        let first = res.log.records.first().unwrap().loss;
        let last = res.log.tail_loss(3).unwrap();
        assert!(last < 0.25 * first, "{first} -> {last}");
        assert!(res.final_eval.unwrap() < 2.0);
    }

    #[test]
    fn ledger_counts_every_step() {
        let res = quick_run(50);
        assert_eq!(res.ledger.steps, 50);
        assert_eq!(res.ledger.fp_rounds, 50); // Adam: one fp round/step
        assert!((res.ledger.bits_per_param() - 16.0).abs() < 1e-9);
    }

    #[test]
    fn clock_accumulates_monotonically() {
        let res = quick_run(20);
        assert!(res.sim_total_s > 0.0);
        let times: Vec<f64> = res.log.records.iter().map(|r| r.sim_total_s).collect();
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        // 20 steps × ≥10ms compute
        assert!(res.sim_total_s >= 0.2);
    }

    #[test]
    fn local_checkpoint_resume_is_bitwise() {
        use crate::runtime::checkpoint::{CheckpointCfg, RunMeta};
        let dir = std::env::temp_dir().join(format!("zo_trainer_ckpt_{}", std::process::id()));
        let dir_s = dir.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&dir);

        let cfg = TrainerConfig {
            steps: 40,
            log_every: 1,
            eval_every: 10,
            fabric: Some(ETHERNET),
            sim_gpus: 8,
            compute_ms: 3.0,
            exec: ExecMode::Sequential,
            topology: Topology::Star,
            verbose: false,
        };
        let meta = RunMeta {
            fingerprint: 0x1234_5678,
            family: "adam".into(),
            d: 24,
            steps: 40,
            world: 4,
            topology: "star".into(),
        };
        let fresh = || {
            (
                NoisyQuadratic::new(24, 5.0, 0.05, 3),
                Adam::new(vec![1.0; 24], 4, Hyper::default(), Box::new(ConstLr(0.05))),
            )
        };

        // Uninterrupted baseline.
        let (mut src, mut opt) = fresh();
        let base = Trainer::run(&mut src, &mut opt, &cfg, &mut NoObserver);

        // Save every 7 steps (last cut at step 35), then resume the
        // tail 35..40 in fresh optimizer/source objects.
        let save = CheckpointCfg {
            dir: dir_s.clone(),
            every: 7,
            resume: false,
            meta: meta.clone(),
        };
        let (mut src, mut opt) = fresh();
        Trainer::run_checkpointed(&mut src, &mut opt, &cfg, &mut NoObserver, &save).unwrap();

        let resume = CheckpointCfg { dir: dir_s, every: 0, resume: true, meta };
        let (mut src, mut opt) = fresh();
        let resumed =
            Trainer::run_checkpointed(&mut src, &mut opt, &cfg, &mut NoObserver, &resume)
                .unwrap();

        assert_eq!(base.final_params.len(), resumed.final_params.len());
        for (a, b) in base.final_params.iter().zip(&resumed.final_params) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(base.sim_total_s.to_bits(), resumed.sim_total_s.to_bits());
        assert_eq!(base.ledger.bytes_total, resumed.ledger.bytes_total);
        assert_eq!(base.ledger.steps, resumed.ledger.steps);
        assert_eq!(base.log.records.len(), resumed.log.records.len());
        for (a, b) in base.log.records.iter().zip(&resumed.log.records) {
            assert_eq!(a.t, b.t);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "t={}", a.t);
            assert_eq!(
                a.eval_loss.map(f64::to_bits),
                b.eval_loss.map(f64::to_bits),
                "t={}",
                a.t
            );
        }
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join(format!(
            "zo_trainer_ckpt_{}",
            std::process::id()
        )));
    }

    #[test]
    fn logs_first_and_last_step() {
        let res = quick_run(23);
        assert_eq!(res.log.records.first().unwrap().t, 0);
        assert_eq!(res.log.records.last().unwrap().t, 22);
        // eval measured at configured cadence
        assert!(res.log.records.iter().any(|r| r.eval_loss.is_some()));
    }
}
