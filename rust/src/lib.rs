//! # 0/1 Adam — ICLR 2023 reproduction
//!
//! Three-layer Rust + JAX + Pallas implementation of *Maximizing
//! Communication Efficiency for Large-scale Training via 0/1 Adam*
//! (Lu, Li, Zhang, De Sa, He).
//!
//! Architecture (see DESIGN.md at the repository root):
//! * [`comm`] — 1-bit codecs + fp16 wire kernels, error-feedback
//!   AllReduce (paper Alg. 2/3) in both in-process and
//!   transport-backed forms, the [`comm::transport`] subsystem (real
//!   multi-process collectives over framed TCP / in-proc channels,
//!   bitwise identical to the in-process engine — DESIGN.md
//!   §Transport), the analytic network-timing model, and the volume
//!   ledger (which under a transport counts actual framed bytes).
//! * [`optim`] — the distributed optimizers: 0/1 Adam (Alg. 1), 1-bit
//!   Adam / frozen-variance family (Alg. 4), original Adam (Eq. 3), SGD
//!   baselines; T_v/T_u policies; LR schedules. Every step is
//!   phase-split into a per-worker local phase and a fixed-order global
//!   reduce/apply phase (DESIGN.md §3), and parameterized over the
//!   reduction backend (`step_comm`: in-process engine or one rank of
//!   a transport group).
//! * [`runtime`] — PJRT loader/executor for AOT HLO artifacts (L2 JAX
//!   graphs with L1 Pallas kernels inlined). Python never runs here.
//!   Offline builds link the vendored `xla` stub (DESIGN.md §1) and
//!   skip artifact-dependent paths at runtime.
//! * [`grad`] — gradient sources (PJRT-backed models + analytical
//!   objectives); pure per-(worker, t) sources expose a thread-shareable
//!   [`grad::ParallelGradients`] view.
//! * [`coordinator`] — the deterministic parallel execution engine
//!   ([`coordinator::engine`]: `ExecMode::{Sequential, Threaded(n)}`,
//!   bitwise-identical by the DESIGN.md §3 contract; a persistent
//!   worker pool with per-slot parking — idle workers sleep through
//!   regions they have no block in — whose regions are
//!   publish–work–barrier cycles; zero-allocation `run_mut`/`run_split`
//!   primitives — both modes — and the fixed-chunk reduction contract
//!   of DESIGN.md §Hot-path), the training loop, the rank-distributed
//!   loop ([`coordinator::distributed`]: `zo-adam launch/worker`,
//!   bitwise parity with the engine), simulated cluster clock,
//!   metrics, Fig-1 profiler.
//! * [`obs`] — the flight recorder (ISSUE 9): per-rank preallocated
//!   ring-buffer phase tracing (all timestamping confined here — the
//!   instrumented modules record opaque `PhaseId`s and stay clean
//!   under lint D1), a metrics registry (log-bucketed latency
//!   histograms, counters), and the versioned JSONL run-event stream
//!   plus chrome://tracing exporter behind `zo-adam trace`.
//! * [`data`] / [`eval`] — synthetic workloads and downstream evals.
//! * [`config`] / [`exp`] — paper workload presets and one driver per
//!   table/figure (DESIGN.md §4).
//! * [`benchkit`] / [`testkit`] — self-contained bench + property-test
//!   harnesses for the offline environment (DESIGN.md §1, §5); property
//!   failures replay exactly via `TESTKIT_SEED`.

pub mod analysis;
pub mod benchkit;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exp;
pub mod grad;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod testkit;
pub mod util;
