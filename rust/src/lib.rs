//! # 0/1 Adam — ICLR 2023 reproduction
//!
//! Three-layer Rust + JAX + Pallas implementation of *Maximizing
//! Communication Efficiency for Large-scale Training via 0/1 Adam*
//! (Lu, Li, Zhang, De Sa, He).
//!
//! Architecture (see DESIGN.md):
//! * [`comm`] — 1-bit codecs, error-feedback AllReduce (paper Alg. 2/3),
//!   the analytic network-timing model, and the volume ledger.
//! * [`optim`] — the distributed optimizers: 0/1 Adam (Alg. 1), 1-bit
//!   Adam / frozen-variance family (Alg. 4), original Adam (Eq. 3), SGD
//!   baselines; T_v/T_u policies; LR schedules.
//! * [`runtime`] — PJRT loader/executor for AOT HLO artifacts (L2 JAX
//!   graphs with L1 Pallas kernels inlined). Python never runs here.
//! * [`grad`] — gradient sources (PJRT-backed models + analytical
//!   objectives).
//! * [`coordinator`] — the training loop, simulated cluster clock,
//!   metrics, Fig-1 profiler.
//! * [`data`] / [`eval`] — synthetic workloads and downstream evals.
//! * [`config`] / [`exp`] — paper workload presets and one driver per
//!   table/figure.
//! * [`benchkit`] / [`testkit`] — self-contained bench + property-test
//!   harnesses (offline environment; see DESIGN.md §1).

pub mod benchkit;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exp;
pub mod grad;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod testkit;
pub mod util;
