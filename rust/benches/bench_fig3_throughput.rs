//! Figure 3 reproduction bench: end-to-end throughput vs GPU count on
//! both fabrics for every task (analytic schedule replay at true paper
//! scale), plus harness timing of the replay itself and — since the
//! execution engine landed — **materialized** parallel step throughput:
//! real wall-clock of the full trainer loop at n = 8 workers, sequential
//! vs threaded, which is what the paper's Fig-3 wall-clock story needs
//! measured honestly.

use zo_adam::benchkit::Bench;
use zo_adam::comm::{ETHERNET, INFINIBAND};
use zo_adam::config::{BERT_BASE, BERT_LARGE, GPT2, IMAGENET};
use zo_adam::coordinator::{ExecMode, NoObserver, Trainer, TrainerConfig};
use zo_adam::exp::analytic::simulate_run;
use zo_adam::exp::{tables, Algo};
use zo_adam::grad::synthetic::NoisyQuadratic;
use zo_adam::optim::policy::{SyncPolicy, SyncSchedule, VarSchedule};
use zo_adam::optim::{ConstLr, Hyper, ZeroOneAdam};

/// Steps/second of a real (materialized) trainer run at d params and
/// n workers under `exec`.
fn materialized_steps_per_sec(d: usize, n: usize, steps: u64, exec: ExecMode) -> f64 {
    let mut src = NoisyQuadratic::new(d, 5.0, 0.1, 11);
    let mut opt = ZeroOneAdam::new(
        vec![0.5f32; d],
        n,
        Hyper::default(),
        Box::new(ConstLr(0.01)),
        VarSchedule::paper(),
        SyncSchedule::new(SyncPolicy::Fixed { interval: 4 }),
    );
    let cfg = TrainerConfig {
        steps,
        log_every: steps,
        exec,
        ..Default::default()
    };
    let res = Trainer::run(&mut src, &mut opt, &cfg, &mut NoObserver);
    steps as f64 / res.wall_s.max(1e-9)
}

fn main() {
    for task in [&BERT_BASE, &BERT_LARGE] {
        for fabric in [&ETHERNET, &INFINIBAND] {
            let t = tables::fig3_throughput(task, fabric, &[4, 8, 16, 32, 64, 128]);
            t.print();
            t.write_csv(&format!("results/fig3_{}_{}.csv", task.name, fabric.name))
                .ok();
        }
    }
    tables::fig3_throughput(&IMAGENET, &ETHERNET, &[4, 8, 16, 32]).print();
    tables::fig3_throughput(&GPT2, &ETHERNET, &[16, 32, 64]).print();

    // The paper's cross-fabric headline.
    let zo_eth = simulate_run(Algo::ZeroOneAdam, &BERT_LARGE, &ETHERNET, 128);
    let ob_ib = simulate_run(Algo::OneBitAdam, &BERT_LARGE, &INFINIBAND, 128);
    println!(
        "\n0/1@Ethernet = {:.0} samples/s vs 1bit@InfiniBand = {:.0} samples/s ({:.2}x)",
        zo_eth.throughput,
        ob_ib.throughput,
        zo_eth.throughput / ob_ib.throughput
    );

    // Harness cost: one full-schedule replay (153K-450K steps).
    let mut b = Bench::new();
    b.run("simulate_run/bert_base/128gpu", || {
        simulate_run(Algo::ZeroOneAdam, &BERT_BASE, &ETHERNET, 128);
    });

    // Materialized wall-clock: the engine's real parallel speedup on
    // this host (0/1 Adam, 8 workers). Bitwise parity between the two
    // modes is enforced by tests/engine_parity_threaded.rs.
    let quick = std::env::var("ZO_BENCH_QUICK").is_ok();
    let (d, steps) = if quick { (1 << 16, 20) } else { (1 << 19, 60) };
    let n = 8;
    // warm up allocators before timing
    materialized_steps_per_sec(d, n, 3, ExecMode::Threaded(8));
    let seq = materialized_steps_per_sec(d, n, steps, ExecMode::Sequential);
    let thr = materialized_steps_per_sec(d, n, steps, ExecMode::Threaded(8));
    println!(
        "\nmaterialized 01adam d={d} n={n}: sequential {seq:.1} steps/s, \
         threaded(8) {thr:.1} steps/s  ({:.2}x, {} cores visible)",
        thr / seq,
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    );
}
