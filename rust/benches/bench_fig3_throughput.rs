//! Figure 3 reproduction bench: end-to-end throughput vs GPU count on
//! both fabrics for every task (analytic schedule replay at true paper
//! scale), plus harness timing of the replay itself.

use zo_adam::benchkit::Bench;
use zo_adam::comm::{ETHERNET, INFINIBAND};
use zo_adam::config::{BERT_BASE, BERT_LARGE, GPT2, IMAGENET};
use zo_adam::exp::analytic::simulate_run;
use zo_adam::exp::{tables, Algo};

fn main() {
    for task in [&BERT_BASE, &BERT_LARGE] {
        for fabric in [&ETHERNET, &INFINIBAND] {
            let t = tables::fig3_throughput(task, fabric, &[4, 8, 16, 32, 64, 128]);
            t.print();
            t.write_csv(&format!("results/fig3_{}_{}.csv", task.name, fabric.name))
                .ok();
        }
    }
    tables::fig3_throughput(&IMAGENET, &ETHERNET, &[4, 8, 16, 32]).print();
    tables::fig3_throughput(&GPT2, &ETHERNET, &[16, 32, 64]).print();

    // The paper's cross-fabric headline.
    let zo_eth = simulate_run(Algo::ZeroOneAdam, &BERT_LARGE, &ETHERNET, 128);
    let ob_ib = simulate_run(Algo::OneBitAdam, &BERT_LARGE, &INFINIBAND, 128);
    println!(
        "\n0/1@Ethernet = {:.0} samples/s vs 1bit@InfiniBand = {:.0} samples/s ({:.2}x)",
        zo_eth.throughput,
        ob_ib.throughput,
        zo_eth.throughput / ob_ib.throughput
    );

    // Harness cost: one full-schedule replay (153K-450K steps).
    let mut b = Bench::new();
    b.run("simulate_run/bert_base/128gpu", || {
        simulate_run(Algo::ZeroOneAdam, &BERT_BASE, &ETHERNET, 128);
    });
}
