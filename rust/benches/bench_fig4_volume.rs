//! Figure 4 reproduction bench: bits/parameter + communication-round
//! reduction across all four paper tasks (exact ledger replay of the
//! full training schedules).

use zo_adam::benchkit::Bench;
use zo_adam::config::BERT_BASE;
use zo_adam::exp::analytic::ledger_for;
use zo_adam::exp::{tables, Algo};

fn main() {
    let t = tables::fig4_volume();
    t.print();
    t.write_csv("results/fig4_volume.csv").ok();

    // Paper headline numbers.
    let zo = ledger_for(Algo::ZeroOneAdam, &BERT_BASE);
    let ob = ledger_for(Algo::OneBitAdam, &BERT_BASE);
    println!(
        "\nBERT-Base: 0/1 Adam reduces data volume by {:.1}% and rounds by {:.1}% vs 1-bit Adam",
        (1.0 - zo.bits_per_param() / ob.bits_per_param()) * 100.0,
        (1.0 - zo.rounds_per_step() / ob.rounds_per_step()) * 100.0
    );
    println!(
        "0/1 Adam average volume: {:.3} bits/param (the \"between 0 and 1 bit\" claim)",
        zo.bits_per_param()
    );

    let mut b = Bench::new();
    b.run("ledger_replay/bert_base/153K-steps", || {
        ledger_for(Algo::ZeroOneAdam, &BERT_BASE);
    });
}
