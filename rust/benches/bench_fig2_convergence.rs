//! Figure 2 reproduction bench: sample-wise + (simulated) time-wise
//! convergence of Adam vs 1-bit Adam vs 0/1 Adam on the BERT proxy
//! with real PJRT gradients.
//!
//! Prints the same series the paper plots (loss at sample/time
//! checkpoints) and the end-to-end speedup factors. Steps default low
//! enough for `cargo bench`; use the CLI (`zo-adam fig2`) for longer
//! runs.

use zo_adam::benchkit::Table;
use zo_adam::config::BERT_BASE;
use zo_adam::exp::convergence::{run_convergence, ConvOpts};
use zo_adam::exp::Algo;
use zo_adam::runtime::Runtime;

fn main() {
    let steps: u64 = std::env::var("ZO_FIG2_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let Ok(rt) = Runtime::new("artifacts") else {
        println!("bench_fig2: artifacts not built (run `make artifacts`); skipping");
        return;
    };
    let mut opts = ConvOpts::quick(&BERT_BASE, steps);
    opts.log_every = (steps / 20).max(1);
    let runs = run_convergence(&rt, &opts, &Algo::main_three()).expect("fig2 run");

    let mut t = Table::new(
        "Figure 2 — BERT-Base proxy convergence (128-GPU Ethernet clock)",
        &["algo", "loss@25%", "loss@50%", "loss@100%", "eval", "sim hours", "time speedup vs adam"],
    );
    let adam_time = runs.iter().find(|(a, _)| *a == Algo::Adam).unwrap().1.sim_total_s;
    for (algo, res) in &runs {
        let at = |frac: f64| {
            let idx = ((res.log.records.len() - 1) as f64 * frac) as usize;
            res.log.records[idx].loss
        };
        t.row(vec![
            algo.name().to_string(),
            format!("{:.4}", at(0.25)),
            format!("{:.4}", at(0.5)),
            format!("{:.4}", at(1.0)),
            format!("{:.4}", res.final_eval.unwrap_or(f32::NAN)),
            format!("{:.2}", res.sim_total_s / 3600.0),
            format!("{:.2}x", adam_time / res.sim_total_s),
        ]);
        res.log
            .write_csv(format!("results/fig2_bench_{}.csv", algo.name()))
            .ok();
    }
    t.print();
    t.write_csv("results/fig2_bench_summary.csv").ok();

    // Paper shape assertions (reported, not fatal):
    let loss_of = |a: Algo| runs.iter().find(|(x, _)| *x == a).unwrap().1.log.tail_loss(3).unwrap();
    let spread = (loss_of(Algo::ZeroOneAdam) - loss_of(Algo::Adam)).abs();
    println!("\nsample-wise parity: |01adam − adam| final loss = {spread:.4}");
    let zo = runs.iter().find(|(a, _)| *a == Algo::ZeroOneAdam).unwrap().1.sim_total_s;
    let ob = runs.iter().find(|(a, _)| *a == Algo::OneBitAdam).unwrap().1.sim_total_s;
    println!("time-wise: 0/1 Adam finishes {:.2}x faster than 1-bit Adam", ob / zo);
}
