//! Microbenchmark: full-precision vs error-feedback 1-bit AllReduce
//! (paper Algorithms 3 and 2) across worker counts.

use zo_adam::benchkit::Bench;
use zo_adam::comm::allreduce::{allreduce_mean, EfAllReduce};
use zo_adam::tensor::Rng;

fn main() {
    println!("== bench_allreduce ==");
    let d = 1 << 20;
    for &n in &[4usize, 16] {
        let mut rng = Rng::new(2);
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let mut out = vec![0.0f32; d];
        let mut ef = EfAllReduce::new(n, d);

        let mut b = Bench::new().with_elements((n * d) as u64);
        b.run(&format!("fp_allreduce/n{n}/1M"), || {
            allreduce_mean(&refs, &mut out);
        });
        b.run(&format!("ef_1bit_allreduce/n{n}/1M"), || {
            ef.reduce(&refs, &mut out);
        });
    }
}
