//! Microbenchmark: full-precision vs error-feedback 1-bit AllReduce
//! (paper Algorithms 3 and 2) across worker counts, sequential vs the
//! chunk-parallel engine path (server leg included since PR 2), and
//! the whole EF round under each forced server-accumulation path
//! (per-worker sweep vs the PR 5 pattern table — bitwise identical,
//! so the delta is pure server-leg throughput).

use zo_adam::benchkit::Bench;
use zo_adam::comm::allreduce::{allreduce_mean_eng, EfAllReduce};
use zo_adam::coordinator::{Engine, ExecMode};
use zo_adam::tensor::Rng;

fn main() {
    println!("== bench_allreduce ==");
    let d = 1 << 20;
    let threads = std::env::var("ZO_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8usize);
    for &n in &[4usize, 16] {
        let mut rng = Rng::new(2);
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let mut out = vec![0.0f32; d];

        for mode in [ExecMode::Sequential, ExecMode::with_threads(threads)] {
            let eng = Engine::new(mode);
            let mut ef = EfAllReduce::new(n, d);
            let mut b = Bench::new()
                .with_elements((n * d) as u64)
                .with_bytes((4 * d * (n + 1)) as u64);
            b.run(&format!("fp_allreduce/n{n}/1M/{}", mode.name()), || {
                allreduce_mean_eng(&bufs, &mut out, &eng);
            });
            b.run(&format!("ef_1bit_allreduce/n{n}/1M/{}", mode.name()), || {
                ef.reduce_eng(&bufs, &mut out, &eng);
            });
            // the same round with the server accumulation pinned to
            // each path (identical bits; only the root leg's speed
            // changes)
            for (path, force) in [("sweep", false), ("table", true)] {
                let mut ef = EfAllReduce::new(n, d);
                ef.force_server_path(Some(force));
                b.run(&format!("ef_1bit_allreduce/n{n}/1M/{}/{path}", mode.name()), || {
                    ef.reduce_eng(&bufs, &mut out, &eng);
                });
            }
        }
    }
}
