//! Microbenchmark: full-precision vs error-feedback 1-bit AllReduce
//! (paper Algorithms 3 and 2) across worker counts, sequential vs the
//! chunk-parallel engine path (server leg included since PR 2), the
//! whole EF round under each forced server-accumulation path
//! (per-worker sweep vs the PR 5 pattern table — bitwise identical,
//! so the delta is pure server-leg throughput), and a flight-recorded
//! per-phase breakdown of the transport round (ISSUE 9).

use zo_adam::benchkit::Bench;
use zo_adam::comm::allreduce::{allreduce_mean_eng, EfAllReduce};
use zo_adam::coordinator::{Engine, ExecMode};
use zo_adam::tensor::Rng;

fn main() {
    println!("== bench_allreduce ==");
    let d = 1 << 20;
    let threads = std::env::var("ZO_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8usize);
    for &n in &[4usize, 16] {
        let mut rng = Rng::new(2);
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let mut out = vec![0.0f32; d];

        for mode in [ExecMode::Sequential, ExecMode::with_threads(threads)] {
            let eng = Engine::new(mode);
            let mut ef = EfAllReduce::new(n, d);
            let mut b = Bench::new()
                .with_elements((n * d) as u64)
                .with_bytes((4 * d * (n + 1)) as u64);
            b.run(&format!("fp_allreduce/n{n}/1M/{}", mode.name()), || {
                allreduce_mean_eng(&bufs, &mut out, &eng);
            });
            b.run(&format!("ef_1bit_allreduce/n{n}/1M/{}", mode.name()), || {
                ef.reduce_eng(&bufs, &mut out, &eng);
            });
            // the same round with the server accumulation pinned to
            // each path (identical bits; only the root leg's speed
            // changes)
            for (path, force) in [("sweep", false), ("table", true)] {
                let mut ef = EfAllReduce::new(n, d);
                ef.force_server_path(Some(force));
                b.run(&format!("ef_1bit_allreduce/n{n}/1M/{}/{path}", mode.name()), || {
                    ef.reduce_eng(&bufs, &mut out, &eng);
                });
            }
        }
    }
    per_phase_breakdown();
}

/// Where a transport round's time goes, from the workers' own flight
/// recorders: a 4-rank in-proc EF round, every worker rank armed. The
/// headline ratio is compress : in-flight — time a worker spends in
/// its own lane compression vs. waiting for the root's broadcast (the
/// window the ROADMAP's overlapped-rounds item wants to hide local
/// compute in).
fn per_phase_breakdown() {
    use zo_adam::comm::transport::inproc;
    use zo_adam::comm::{RankLink, Topology, SERVER_CHUNK};
    use zo_adam::obs::{self, PhaseId, Registry};

    let d = 4 * SERVER_CHUNK + 321;
    let world = 4usize;
    println!("\n-- per-phase round breakdown (n = {world}, in-proc transport, traced) --");
    let mut rng = Rng::new(9);
    let mut links: Vec<RankLink> = inproc::group_topo(world, Topology::Star)
        .into_iter()
        .map(|tp| {
            let mut link = RankLink::new(Box::new(tp));
            link.set_topology(Topology::Star);
            link
        })
        .collect();
    let workers: Vec<_> = links
        .drain(1..)
        .map(|mut link| {
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            std::thread::spawn(move || {
                obs::arm(obs::DEFAULT_CAPACITY);
                let mut ef = EfAllReduce::new(1, d);
                let bufs = vec![g];
                let mut out = vec![0.0f32; d];
                // run until the root hangs up, then hand the recorded
                // stream back for aggregation
                while ef.reduce_transport(&bufs, &mut out, &mut link).is_ok() {}
                obs::disarm().map(|rec| rec.events()).unwrap_or_default()
            })
        })
        .collect();
    let mut root_link = links.pop().expect("rank 0");
    let mut ef = EfAllReduce::new(1, d);
    let mut g0 = vec![0.0f32; d];
    rng.fill_normal(&mut g0, 1.0);
    let bufs = vec![g0];
    let mut out = vec![0.0f32; d];
    let mut b = Bench::new().with_elements(d as u64);
    b.run(&format!("ef_1bit_transport/n{world}/round"), || {
        ef.reduce_transport(&bufs, &mut out, &mut root_link).expect("root round");
    });
    drop(root_link); // hang up: the workers' next recv is Closed
    let mut reg = Registry::new();
    for w in workers {
        reg.ingest_events(&w.join().expect("breakdown worker"));
    }
    print!("{}", reg.render_table());
    let compress = reg.span(PhaseId::Compress).sum_ns();
    let in_flight = reg.span(PhaseId::Broadcast).sum_ns();
    if in_flight > 0 {
        println!(
            "  -> compress : in-flight = {:.3} (worker compute per ns of broadcast wait; \
             {} unbalanced span(s) from ring wrap)",
            compress as f64 / in_flight as f64,
            reg.unbalanced,
        );
    }
}
