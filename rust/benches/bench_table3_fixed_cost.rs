//! Table 3 reproduction bench: per-step computation vs per-round fixed
//! cost across cluster scales — the decomposition that motivates local
//! steps (fixed costs grow with scale while computation shrinks).

use zo_adam::comm::ETHERNET;
use zo_adam::config::BERT_BASE;
use zo_adam::exp::tables;

fn main() {
    let t = tables::table3_fixed_cost();
    t.print();
    t.write_csv("results/table3_fixed_cost.csv").ok();

    // The crossover the paper argues from: at 128 GPUs the fixed cost
    // exceeds half the computation for BERT-class models.
    let cm = BERT_BASE.compute_model();
    let fixed = ETHERNET.fixed_cost_ms(BERT_BASE.d, 128);
    println!(
        "\nBERT-Base @128 GPUs: computation {:.0} ms vs fixed cost {:.0} ms — skipping rounds \
         (local steps) is the only way past this floor",
        cm.step_ms(128),
        fixed
    );
}
