//! Figure 5 reproduction bench: 0/1 Adam *without* round skipping
//! (T_u = every step). The paper's point: variance freezing alone gets
//! the volume to ~1 bit/param, but without local steps the throughput
//! gain over 1-bit Adam collapses at scale — the fixed per-round cost
//! dominates (Table 3).

use zo_adam::comm::ETHERNET;
use zo_adam::config::{BERT_BASE, BERT_LARGE};
use zo_adam::exp::analytic::simulate_run;
use zo_adam::exp::{tables, Algo};

fn main() {
    let t = tables::fig5_ablation(&ETHERNET, &[16, 32, 64, 128]);
    t.print();
    t.write_csv("results/fig5_ablation.csv").ok();

    for task in [&BERT_BASE, &BERT_LARGE] {
        let zo = simulate_run(Algo::ZeroOneAdam, task, &ETHERNET, 128);
        let nl = simulate_run(Algo::ZeroOneNoLocal, task, &ETHERNET, 128);
        let ob = simulate_run(Algo::OneBitAdam, task, &ETHERNET, 128);
        println!(
            "{}@128: full 0/1 = {:.2}x over 1-bit; without local steps only {:.2}x \
             (local steps contribute {:.0}% of the gain)",
            task.name,
            zo.throughput / ob.throughput,
            nl.throughput / ob.throughput,
            100.0 * (zo.throughput - nl.throughput) / (zo.throughput - ob.throughput).max(1e-9)
        );
    }
}
