//! Microbenchmark: one full optimizer step per algorithm at d = 1M,
//! n = 8 materialized workers (the L3 hot loop), **sequential vs the
//! threaded engine**, plus the PJRT-executed Pallas kernel path for the
//! 0/1 Adam local step (the L1 hot loop).
//!
//! The engine contract makes the two modes bitwise identical (verified
//! by `tests/engine_parity_threaded.rs`); this bench reports the
//! wall-clock side of the story — the per-step throughput speedup of
//! `ExecMode::Threaded(8)` over `ExecMode::Sequential`.
//!
//! Env knobs: `ZO_BENCH_QUICK=1` (short measurement windows),
//! `ZO_BENCH_D` (override d, e.g. 262144 for a CI smoke),
//! `ZO_BENCH_THREADS` (override pool width, default 8).

use zo_adam::benchkit::Bench;
use zo_adam::coordinator::{Engine, ExecMode};
use zo_adam::exp::convergence::{build_optimizer, ConvOpts};
use zo_adam::exp::Algo;
use zo_adam::optim::DistOptimizer;
use zo_adam::runtime::{golden_vec, HostTensor, Runtime};
use zo_adam::tensor::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    println!("== bench_optimizer ==");
    let d = env_usize("ZO_BENCH_D", 1 << 20);
    let n = 8;
    let threads = env_usize("ZO_BENCH_THREADS", 8);
    let opts = ConvOpts {
        workers: n,
        ..ConvOpts::quick(&zo_adam::config::BERT_BASE, 100_000)
    };
    let mut rng = Rng::new(3);
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 0.1);
            v
        })
        .collect();

    println!("d = {d}, workers = {n}, pool = {threads} threads\n");
    for algo in [Algo::Adam, Algo::OneBitAdam, Algo::ZeroOneAdam, Algo::ZeroOneNoLocal] {
        let mut results = Vec::new();
        for mode in [ExecMode::Sequential, ExecMode::Threaded(threads)] {
            let engine = Engine::new(mode);
            let mut opt = build_optimizer(algo, vec![0.0f32; d], &opts);
            let mut t = 0u64;
            let mut b = Bench::new().with_elements(d as u64);
            let r = b.run(&format!("step/{}/{}/d{d}/n{n}", algo.name(), mode.name()), || {
                opt.step_engine(t, &grads, &engine);
                t += 1;
            });
            results.push(r.mean_ns);
        }
        println!(
            "  -> {}: threaded({threads}) speedup over sequential: {:.2}x\n",
            algo.name(),
            results[0] / results[1]
        );
    }

    // L1 path: the lowered Pallas zo_local_step via PJRT (artifact d).
    if let Ok(rt) = Runtime::new("artifacts") {
        let model = "lm_small";
        if let Ok(exe) = rt.load(model, "zo_local_step") {
            let kd = rt.manifest.model(model).unwrap().param_count;
            let inputs = vec![
                HostTensor::f32(vec![1e-3], &[1]),
                HostTensor::f32(golden_vec(kd, 0.3, 0.1), &[kd]),
                HostTensor::f32(golden_vec(kd, 1.1, 0.05), &[kd]),
                HostTensor::f32(golden_vec(kd, 3.7, 1.0), &[kd]),
                HostTensor::f32(golden_vec(kd, 4.9, 0.02), &[kd]),
                HostTensor::f32(golden_vec(kd, 2.3, 0.2).iter().map(|v| v.abs() + 1.0).collect(), &[kd]),
            ];
            let mut b = Bench::new().with_elements(kd as u64);
            b.run(&format!("pallas_zo_local_step/pjrt/{model}"), || {
                exe.run(&inputs).unwrap();
            });
        }
    } else {
        println!("(artifacts not built; skipping PJRT kernel bench)");
    }
}
