//! Microbenchmark: one full optimizer step per algorithm at d = 1M,
//! n = 4 workers (the L3 hot loop), plus the PJRT-executed Pallas
//! kernel path for the 0/1 Adam local step (the L1 hot loop).

use zo_adam::benchkit::Bench;
use zo_adam::exp::convergence::{build_optimizer, ConvOpts};
use zo_adam::exp::Algo;
use zo_adam::runtime::{golden_vec, HostTensor, Runtime};
use zo_adam::tensor::Rng;

fn main() {
    println!("== bench_optimizer ==");
    let d = 1 << 20;
    let n = 4;
    let opts = ConvOpts::quick(&zo_adam::config::BERT_BASE, 100_000);
    let mut rng = Rng::new(3);
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 0.1);
            v
        })
        .collect();

    for algo in [Algo::Adam, Algo::OneBitAdam, Algo::ZeroOneAdam, Algo::ZeroOneNoLocal] {
        let mut opt = build_optimizer(algo, vec![0.0f32; d], &opts);
        let mut t = 0u64;
        let mut b = Bench::new().with_elements(d as u64);
        b.run(&format!("step/{}/d1M/n4", algo.name()), || {
            opt.step(t, &grads);
            t += 1;
        });
    }

    // L1 path: the lowered Pallas zo_local_step via PJRT (artifact d).
    if let Ok(rt) = Runtime::new("artifacts") {
        let model = "lm_small";
        if let Ok(exe) = rt.load(model, "zo_local_step") {
            let kd = rt.manifest.model(model).unwrap().param_count;
            let inputs = vec![
                HostTensor::f32(vec![1e-3], &[1]),
                HostTensor::f32(golden_vec(kd, 0.3, 0.1), &[kd]),
                HostTensor::f32(golden_vec(kd, 1.1, 0.05), &[kd]),
                HostTensor::f32(golden_vec(kd, 3.7, 1.0), &[kd]),
                HostTensor::f32(golden_vec(kd, 4.9, 0.02), &[kd]),
                HostTensor::f32(golden_vec(kd, 2.3, 0.2).iter().map(|v| v.abs() + 1.0).collect(), &[kd]),
            ];
            let mut b = Bench::new().with_elements(kd as u64);
            b.run(&format!("pallas_zo_local_step/pjrt/{model}"), || {
                exe.run(&inputs).unwrap();
            });
        }
    } else {
        println!("(artifacts not built; skipping PJRT kernel bench)");
    }
}
