//! Microbenchmark: the 1-bit codec hot path (compress / decompress /
//! accumulate / fused compress+error) at realistic buffer sizes.
//!
//! This is the L3 analogue of the paper's compression-kernel cost (the
//! dominant share of Table 3's "Others" column).

use zo_adam::benchkit::Bench;
use zo_adam::comm::compress::{self, OneBit};
use zo_adam::tensor::Rng;

fn main() {
    println!("== bench_compression ==");
    for &d in &[1usize << 20, 12 << 20] {
        let mut rng = Rng::new(1);
        let mut src = vec![0.0f32; d];
        rng.fill_normal(&mut src, 1.0);
        let mut packed = OneBit::zeros(d);
        let mut err = vec![0.0f32; d];
        let mut dense = vec![0.0f32; d];
        let label = format!("{}M", d >> 20);

        // Throughput in GB/s over the f32 source stream (4 bytes per
        // coordinate per sweep) — the number that matters on a
        // memory-bound codec.
        let mut b = Bench::new().with_elements(d as u64).with_bytes((4 * d) as u64);
        b.run(&format!("compress_into/{label}"), || {
            compress::compress_into(&src, &mut packed);
        });
        b.run(&format!("compress_with_error/{label}"), || {
            compress::compress_with_error_into(&src, &mut packed, &mut err);
        });
        b.run(&format!("compress_ef_fused/{label}"), || {
            compress::compress_ef_into(&src, &mut err, &mut packed);
        });
        b.run(&format!("decompress_into/{label}"), || {
            compress::decompress_into(&packed, &mut dense);
        });
        b.run(&format!("accumulate_into/{label}"), || {
            compress::accumulate_into(&packed, 0.25, &mut dense);
        });

        // fp16 wire buffers (ISSUE 4): the pack/unpack bandwidth the
        // clock model has always charged the full-precision AllReduce
        // for — now a real kernel, measured over the same 4 B/coord
        // source-stream basis as the 1-bit codec above.
        let mut halves = vec![0u16; d];
        b.run(&format!("pack_fp16/{label}"), || {
            compress::pack_fp16(&src, &mut halves);
        });
        b.run(&format!("unpack_fp16/{label}"), || {
            compress::unpack_fp16(&halves, &mut dense);
        });
        b.run(&format!("fp16_roundtrip_add/{label}"), || {
            compress::add_fp16_rounded(&mut dense, &src);
        });
    }
}
