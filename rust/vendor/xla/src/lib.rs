//! Offline stub of the `xla` PJRT bindings.
//!
//! The build environment ships neither crates.io access nor the
//! `xla_extension` shared library, so this crate provides the exact API
//! surface `zo_adam::runtime` compiles against. Every entry point that
//! would reach PJRT fails cleanly at runtime ([`PjRtClient::cpu`]
//! returns an error), which the callers already handle: all
//! artifact-dependent tests and benches skip when no runtime can be
//! constructed.
//!
//! Swapping in the real bindings is a one-line Cargo change; no source
//! edits are required.

/// Error type mirrored from the real bindings (callers format it with
/// `{:?}`).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: PJRT/xla_extension is not available in this offline build (xla stub crate)"
    )))
}

/// Host-side literal (stub: carries no data — nothing can execute).
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal::default()
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable("Literal::to_tuple")
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation built from a proto.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// In the stub build no backend exists; constructing a client fails,
    /// which downstream code treats as "runtime not available".
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("not available"));
        assert!(format!("{err}").contains("xla stub"));
    }

    #[test]
    fn literal_surface_compiles_and_fails_cleanly() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.to_tuple().is_err());
        let _ = Literal::vec1(&[1i32]);
    }

    #[test]
    fn hlo_parsing_fails_cleanly() {
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
    }
}
