//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the workspace uses:
//!
//! * [`Error`] — a context-chained, boxed-free error value;
//! * [`Result<T>`] — `Result<T, Error>` with the same default-type-param
//!   shape as upstream;
//! * [`anyhow!`] / [`ensure!`] / [`bail!`] — format-style constructors;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on any
//!   `Result<_, E: Into<Error>>`.
//!
//! Semantics mirror upstream where the workspace depends on them:
//! `{}` prints the outermost message, `{:#}` prints the full chain
//! joined by `: `, and `{:?}` prints a `Caused by:` stack.

use std::fmt;

/// A context-chained error value. The newest context is first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line, like upstream anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::msg(err)
    }
}

/// `anyhow::Result<T>` — second parameter defaulted like upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and lazily with `with_context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert!(format!("{e:?}").contains("Caused by:"));
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing"));
    }

    #[test]
    fn context_on_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "reading x");
        let r2: Result<()> = Err(anyhow!("base {}", 3));
        let e2 = r2.context("top").unwrap_err();
        assert_eq!(format!("{e2:#}"), "top: base 3");
    }

    #[test]
    fn macros_build_errors() {
        fn check(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(7)
        }
        assert_eq!(check(true).unwrap(), 7);
        assert!(check(false).is_err());
        fn always() -> Result<u32> {
            bail!("nope {}", 1);
        }
        assert!(always().is_err());
    }
}
