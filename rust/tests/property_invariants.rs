//! Property-based coordinator/optimizer invariants (mini-proptest).
//!
//! These are the randomized invariants DESIGN.md §6 calls out:
//! compression contraction, EF consensus, 0/1 Adam worker consensus at
//! sync steps, volume-ledger-vs-closed-form, clock monotonicity.

use zo_adam::comm::allreduce::{allreduce_mean, EfAllReduce};
use zo_adam::comm::{compress, decompress_into, wire_bytes, VolumeLedger};
use zo_adam::coordinator::{NoObserver, Trainer, TrainerConfig};
use zo_adam::grad::synthetic::NoisyQuadratic;
use zo_adam::grad::GradientSource;
use zo_adam::optim::policy::{SyncPolicy, SyncSchedule, VarPolicy, VarSchedule};
use zo_adam::optim::{ConstLr, DistOptimizer, Hyper, ZeroOneAdam};
use zo_adam::testkit::{property, Gen};

#[test]
fn prop_compression_is_contraction_and_l1_preserving() {
    property(150, |g: &mut Gen| {
        let v = g.vec_normal(1..2000, 2.0);
        let packed = compress(&v);
        let mut dense = vec![0.0f32; v.len()];
        decompress_into(&packed, &mut dense);
        // ||C[x] - x|| <= ||x|| (empirical Assumption 6, ω ≤ 1)
        let err: f64 = dense
            .iter()
            .zip(&v)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let norm = zo_adam::tensor::norm2(&v);
        assert!(err <= norm * (1.0 + 1e-6), "err {err} > norm {norm}");
        // exact L1 preservation
        let (l1a, l1b) = (zo_adam::tensor::norm1(&dense), zo_adam::tensor::norm1(&v));
        assert!((l1a - l1b).abs() <= 1e-4 * l1b.max(1.0));
        // exact wire size
        assert_eq!(packed.wire_bytes(), wire_bytes(v.len()));
    });
}

#[test]
fn prop_codec_handles_lengths_off_the_word_boundary() {
    // The sign bitmap packs 64 coordinates per u64; every length class
    // around the word boundary must roundtrip exactly.
    property(120, |g: &mut Gen| {
        // d ≡ r (mod 64) with r drawn over the full residue range,
        // including r = 1 and r = 63
        let words = g.usize_in(0..4);
        let r = g.usize_in(1..64);
        let d = words * 64 + r;
        let v = g.vec_normal(d..d + 1, 1.5);
        let packed = compress(&v);
        assert_eq!(packed.len, d);
        assert_eq!(packed.signs.len(), d.div_ceil(64));
        assert_eq!(packed.wire_bytes(), wire_bytes(d));
        let mut dense = vec![0.0f32; d];
        decompress_into(&packed, &mut dense);
        // exact sign/scale semantics per coordinate (the reference here
        // replicates the codec's accumulation order: f32 within each
        // 64-chunk, f64 across chunks — so the comparison is bitwise)
        let mut l1 = 0.0f64;
        for chunk in v.chunks(64) {
            let mut csum = 0.0f32;
            for &x in chunk {
                csum += x.abs();
            }
            l1 += csum as f64;
        }
        let scale = (l1 / d as f64) as f32;
        assert_eq!(packed.scale.to_bits(), scale.to_bits());
        for j in 0..d {
            assert_eq!(dense[j] >= 0.0, v[j] >= 0.0, "sign at {j}");
            assert_eq!(dense[j].abs().to_bits(), packed.scale.to_bits(), "mag at {j}");
        }
    });
}

#[test]
fn codec_all_zero_and_single_element_vectors() {
    // all-zero: scale 0, every output is positive zero (sign(0) = +1)
    for d in [1usize, 5, 63, 64, 65, 200] {
        let v = vec![0.0f32; d];
        let packed = compress(&v);
        assert_eq!(packed.scale, 0.0);
        let mut dense = vec![1.0f32; d];
        decompress_into(&packed, &mut dense);
        for (j, o) in dense.iter().enumerate() {
            assert_eq!(o.to_bits(), 0.0f32.to_bits(), "d={d} j={j} not +0.0");
        }
    }
    // single element: scale = |x|, sign preserved exactly
    for x in [3.5f32, -3.5, 0.25, -1e-30] {
        let packed = compress(&[x]);
        assert_eq!(packed.scale, x.abs());
        let mut out = [0.0f32];
        decompress_into(&packed, &mut out);
        assert_eq!(out[0], x);
    }
}

#[test]
fn codec_signed_zero_maps_to_positive() {
    // The codec's contract (matching the Pallas kernel and ref.py):
    // sign(±0) = +1, so both zeros compress to the positive branch.
    let v = [0.0f32, -0.0, -1.0, 2.0];
    let packed = compress(&v);
    let mut out = vec![0.0f32; 4];
    decompress_into(&packed, &mut out);
    assert!(out[0] > 0.0 && out[1] > 0.0, "±0 must take the + branch");
    assert!(out[2] < 0.0 && out[3] > 0.0);
    // an all-±0 vector decompresses to all +0.0 bit patterns
    let z = [-0.0f32, 0.0, -0.0];
    let pz = compress(&z);
    assert_eq!(pz.scale, 0.0);
    let mut oz = vec![9.0f32; 3];
    decompress_into(&pz, &mut oz);
    for o in &oz {
        assert_eq!(o.to_bits(), 0, "expected +0.0 bits");
    }
}

#[test]
fn prop_codec_error_feedback_roundtrip_on_odd_lengths() {
    // compress_with_error_into + decompress_into telescope exactly for
    // lengths straddling the word boundary.
    property(60, |g: &mut Gen| {
        let d = g.usize_in(1..300);
        let v = g.vec_normal(d..d + 1, 2.0);
        let mut packed = zo_adam::comm::OneBit::zeros(d);
        let mut err = vec![0.0f32; d];
        zo_adam::comm::compress::compress_with_error_into(&v, &mut packed, &mut err);
        let mut q = vec![0.0f32; d];
        decompress_into(&packed, &mut q);
        for j in 0..d {
            assert!((q[j] + err[j] - v[j]).abs() <= 1e-5, "j={j}");
        }
    });
}

#[test]
fn prop_ef_allreduce_broadcast_is_shared_and_one_valued() {
    property(60, |g: &mut Gen| {
        let n = g.usize_in(1..6);
        let d = g.usize_in(1..500);
        let mut ef = EfAllReduce::new(n, d);
        let mut out = vec![0.0f32; d];
        for _round in 0..g.usize_in(1..4) {
            let bufs: Vec<Vec<f32>> = (0..n).map(|_| {
                let mut v = vec![0.0f32; d];
                for x in v.iter_mut() {
                    *x = g.f32_in(-3.0, 3.0);
                }
                v
            }).collect();
            let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
            let stats = ef.reduce(&refs, &mut out);
            // single magnitude (the 1-bit property)
            let mag = out[0].abs();
            assert!(out.iter().all(|v| (v.abs() - mag).abs() <= 1e-6 * mag.max(1.0)));
            assert!(stats.compressed);
            assert_eq!(stats.up_bytes, wire_bytes(d) as u64);
        }
    });
}

#[test]
fn prop_fp_allreduce_is_permutation_invariant_mean() {
    property(60, |g: &mut Gen| {
        let n = g.usize_in(2..6);
        let d = g.usize_in(1..300);
        let bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(d..d + 1, 1.0)).collect();
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let mut out1 = vec![0.0f32; d];
        allreduce_mean(&refs, &mut out1);
        let mut rev: Vec<&[f32]> = refs.clone();
        rev.reverse();
        let mut out2 = vec![0.0f32; d];
        allreduce_mean(&rev, &mut out2);
        for i in 0..d {
            // ISSUE 4: the fp AllReduce models the fp16 wire, so the
            // broadcast is fp16-rounded — reversing the accumulation
            // order can shift the f32 sum by an ulp, which the final
            // rounding may widen to one fp16 ulp (~4.9e-4 relative).
            let tol = 1e-3 * out1[i].abs().max(1.0);
            assert!((out1[i] - out2[i]).abs() <= tol, "i={i}: {} vs {}", out1[i], out2[i]);
        }
    });
}

#[test]
fn prop_zeroone_consensus_and_anchor_invariants() {
    property(25, |g: &mut Gen| {
        let d = g.usize_in(4..64);
        let n = g.usize_in(2..5);
        let interval = g.u64_in(1..6);
        let steps = g.u64_in(10..40);
        let mut opt = ZeroOneAdam::new(
            vec![0.5f32; d],
            n,
            Hyper::default(),
            Box::new(ConstLr(g.f64_in(1e-4, 5e-2))),
            VarSchedule::new(VarPolicy::ExpInterval { kappa: 4 }),
            SyncSchedule::new(SyncPolicy::Fixed { interval }),
        );
        let mut src = NoisyQuadratic::new(d, 3.0, 0.2, g.case_seed);
        let mut grads = vec![vec![0.0f32; d]; n];
        for t in 0..steps {
            for w in 0..n {
                let p = opt.params(w).to_vec();
                src.grad(&p, w, t, &mut grads[w]);
            }
            let info = opt.step(t, &grads);
            if info.synced {
                // bit-exact consensus after every sync: every replica
                // equals worker 0 (consensus_error() itself goes through
                // an f32 mean, which can round by 1 ulp for n=3).
                for w in 1..n {
                    assert_eq!(opt.params(w), opt.params(0), "t={t}");
                }
            }
            // all states finite
            for w in 0..n {
                assert!(opt.params(w).iter().all(|v| v.is_finite()), "t={t}");
            }
        }
    });
}

#[test]
fn prop_ledger_matches_closed_form() {
    property(40, |g: &mut Gen| {
        let d = g.usize_in(1..100_000);
        let steps = g.u64_in(1..200);
        let every = g.u64_in(1..8);
        let mut ledger = VolumeLedger::new(d);
        let fp = zo_adam::exp::analytic::fp_round(d);
        let ob = zo_adam::exp::analytic::onebit_round(d);
        let mut fp_count = 0u64;
        let mut ob_count = 0u64;
        for t in 0..steps {
            if t % every == 0 {
                ledger.record_step(&[ob]);
                ob_count += 1;
            } else if t % 3 == 1 {
                ledger.record_step(&[fp]);
                fp_count += 1;
            } else {
                ledger.record_step(&[]);
            }
        }
        let expect_bytes =
            fp_count * 4 * d as u64 + ob_count * 2 * wire_bytes(d) as u64;
        assert_eq!(ledger.bytes_total, expect_bytes);
        assert_eq!(ledger.fp_rounds, fp_count);
        assert_eq!(ledger.onebit_rounds, ob_count);
        let bits = (expect_bytes / 2) as f64 * 8.0 / (d as f64 * steps as f64);
        assert!((ledger.bits_per_param() - bits).abs() < 1e-9);
    });
}

#[test]
fn prop_trainer_clock_monotone_and_complete() {
    property(15, |g: &mut Gen| {
        let d = g.usize_in(8..64);
        let steps = g.u64_in(5..50);
        let mut src = NoisyQuadratic::new(d, 2.0, 0.1, g.case_seed);
        let mut opt = ZeroOneAdam::new(
            vec![1.0f32; d],
            2,
            Hyper::default(),
            Box::new(ConstLr(0.01)),
            VarSchedule::paper(),
            SyncSchedule::new(SyncPolicy::Fixed { interval: g.u64_in(1..4) }),
        );
        let cfg = TrainerConfig {
            steps,
            log_every: 1,
            fabric: Some(zo_adam::comm::ETHERNET),
            sim_gpus: *g.choose(&[8usize, 32, 128]),
            compute_ms: g.f64_in(1.0, 100.0),
            ..Default::default()
        };
        let res = Trainer::run(&mut src, &mut opt, &cfg, &mut NoObserver);
        assert_eq!(res.log.records.len(), steps as usize);
        let mut prev = 0.0;
        for r in &res.log.records {
            assert!(r.sim_total_s >= prev);
            assert!(r.sim_ms >= cfg.compute_ms - 1e-9);
            prev = r.sim_total_s;
        }
        assert_eq!(res.ledger.steps, steps);
    });
}

#[test]
fn prop_policies_emit_sorted_unique_steps() {
    property(60, |g: &mut Gen| {
        let kappa = g.usize_in(1..20) as u32;
        let mut vs = VarSchedule::new(VarPolicy::ExpInterval { kappa });
        let horizon = g.u64_in(10..2000);
        let mut last: Option<u64> = None;
        let mut count = 0u64;
        for t in 0..horizon {
            if vs.is_update_step(t) {
                if let Some(l) = last {
                    assert!(t > l);
                }
                last = Some(t);
                count += 1;
            }
        }
        assert_eq!(vs.updates(), count);
        assert!(count >= 1);
        // gaps grow: the number of updates is O(kappa * log2(horizon))
        let bound = kappa as u64 * (64 - horizon.leading_zeros() as u64 + 2) + 2;
        assert!(count <= bound, "count {count} > bound {bound} (kappa={kappa}, T={horizon})");
    });
}
