//! Per-rule fixtures for `zo-adam lint` (ISSUE 8): every rule gets a
//! triggering fixture and a clean one, the directive grammar
//! (`lint: allow(...)`, `lint: hot-path`) is exercised end to end, and
//! the W1 demo shows that renumbering a pinned frame kind in the
//! source tree turns the lint red against the committed `wire.lock`.
//!
//! Fixtures live in string literals. The analyzer works on the token
//! stream, so the banned idioms quoted here are opaque to `lint_self`
//! — this file itself still lints clean.

use std::path::Path;

use zo_adam::analysis::{
    check_lock, extract_wire_surface, lint_source, resolve_root, Finding, RuleId, Severity,
    WIRE_FILES,
};

fn fired(findings: &[Finding]) -> Vec<RuleId> {
    findings.iter().map(|f| f.rule).collect()
}

// --- D1: ambient time, unordered containers, ambient randomness ----------

#[test]
fn d1_triggers_on_time_containers_and_rng() {
    let src = "fn f() {\n    let t = Instant::now();\n    let m: HashMap<u32, u32> = HashMap::with_capacity(4);\n    let r = thread_rng();\n}\n";
    let f = lint_source("rust/src/optim/adam.rs", src);
    // Instant::now once, HashMap twice (type + ctor), thread_rng once.
    assert_eq!(fired(&f), vec![RuleId::D1; 4], "{f:?}");
    assert!(f.iter().all(|x| x.severity == Severity::Deny));
    assert_eq!(f[0].line, 2);
}

#[test]
fn d1_is_silent_outside_its_scope_and_in_tests() {
    let src = "fn f() { let t = Instant::now(); }\n";
    assert!(lint_source("rust/src/benchkit/mod.rs", src).is_empty());
    assert!(lint_source("rust/src/trainer.rs", src).is_empty());
    let gated = "#[cfg(test)]\nmod tests {\n    fn f() { let t = Instant::now(); }\n}\n";
    assert!(lint_source("rust/src/optim/adam.rs", gated).is_empty());
}

// --- D2: unordered float reductions --------------------------------------

#[test]
fn d2_triggers_on_sum_product_fold() {
    let src = "fn f(v: &[f32]) -> f32 {\n    let a: f32 = v.iter().sum();\n    let b = v.iter().product::<f32>();\n    v.iter().fold(a, |x, y| x + y) + b\n}\n";
    let f = lint_source("rust/src/comm/allreduce.rs", src);
    assert_eq!(fired(&f), vec![RuleId::D2; 3], "{f:?}");
    assert_eq!(f.iter().map(|x| x.line).collect::<Vec<_>>(), vec![2, 3, 4]);
}

#[test]
fn d2_leaves_ordered_loops_and_unscoped_files_alone() {
    // The fixed-chunk kernel shape: an explicit ordered loop.
    let ordered = "fn f(v: &[f32]) -> f32 {\n    let mut acc = 0.0;\n    for x in v {\n        acc += x;\n    }\n    acc\n}\n";
    assert!(lint_source("rust/src/comm/allreduce.rs", ordered).is_empty());
    // `.sum()` is fine off the parity-critical path.
    let src = "fn f(v: &[f32]) -> f32 { v.iter().sum() }\n";
    assert!(lint_source("rust/src/benchkit/stats.rs", src).is_empty());
}

// --- A1: allocation idioms in hot-path-marked functions -------------------

#[test]
fn a1_fires_only_inside_hot_marked_bodies() {
    let src = "// lint: hot-path\nfn hot(n: usize) -> usize {\n    let v = vec![0u8; n];\n    v.len()\n}\nfn cold(n: usize) -> usize {\n    let v = vec![1u8; n];\n    v.len()\n}\n";
    let f = lint_source("rust/src/comm/compress.rs", src);
    assert_eq!(fired(&f), vec![RuleId::A1], "{f:?}");
    assert_eq!(f[0].line, 3, "only the marked body is patrolled: {f:?}");
}

#[test]
fn a1_catches_the_full_idiom_set() {
    let src = "// lint: hot-path\nfn hot() {\n    let a = Vec::new();\n    let b = x.collect::<Vec<u32>>();\n    let c = s.to_vec();\n    let d = format!(\"x\");\n    let e = Box::new(1);\n    let f = String::from(\"y\");\n}\n";
    let f = lint_source("rust/src/comm/compress.rs", src);
    assert_eq!(fired(&f), vec![RuleId::A1; 6], "{f:?}");
}

// --- E1: panicking idioms in comm::transport ------------------------------

#[test]
fn e1_triggers_on_unwrap_expect_panic() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    let a = x.unwrap();\n    let b = x.expect(\"gone\");\n    if a != b { panic!(\"mismatch\"); }\n    a\n}\n";
    let f = lint_source("rust/src/comm/transport/tcp.rs", src);
    assert_eq!(fired(&f), vec![RuleId::E1; 3], "{f:?}");
}

#[test]
fn e1_spares_the_protocol_expect_and_tests() {
    // `FrameHeader::expect(kind, …)` takes no string message — it is
    // the wire validation method, not a panic.
    let protocol =
        "fn f() -> Result<(), E> {\n    header.expect(FrameKind::Ef, from, seq, dim, chunk)?;\n    Ok(())\n}\n";
    assert!(lint_source("rust/src/comm/transport/tcp.rs", protocol).is_empty());
    let in_test = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); }\n}\n";
    assert!(lint_source("rust/src/comm/transport/tcp.rs", in_test).is_empty());
    // And the whole rule is scoped to the transport layer.
    let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    assert!(lint_source("rust/src/comm/compress.rs", src).is_empty());
}

// --- U1: SAFETY comments on unsafe ----------------------------------------

#[test]
fn u1_requires_an_adjacent_safety_comment_everywhere() {
    let bare = "fn f(p: *mut u32) {\n    unsafe { *p = 1 };\n}\n";
    let f = lint_source("rust/src/tensor.rs", bare);
    assert_eq!(fired(&f), vec![RuleId::U1], "{f:?}");
    // Tests are NOT exempt: an unsound test is still unsound.
    let in_test = "#[cfg(test)]\nmod tests {\n    fn t(p: *mut u32) { unsafe { *p = 1 } }\n}\n";
    assert_eq!(fired(&lint_source("rust/src/tensor.rs", in_test)), vec![RuleId::U1]);
}

#[test]
fn u1_accepts_a_safety_comment_in_the_window() {
    let ok = "// SAFETY: p is valid for writes, caller contract.\nfn f(p: *mut u32) {\n    unsafe { *p = 1 };\n}\n";
    assert!(lint_source("rust/src/tensor.rs", ok).is_empty());
    // Function-pointer *types* carry no obligation of their own.
    let fnptr = "struct Task { run: unsafe fn(*mut ()) }\n";
    assert!(lint_source("rust/src/tensor.rs", fnptr).is_empty());
}

// --- The directive grammar -------------------------------------------------

#[test]
fn allow_suppresses_exactly_its_target_line() {
    let trailing = "fn f() {\n    let t = Instant::now(); // lint: allow(D1) — deadline arming, not reduction order\n    let u = Instant::now();\n}\n";
    let f = lint_source("rust/src/comm/transport/tcp.rs", trailing);
    assert_eq!(fired(&f), vec![RuleId::D1], "{f:?}");
    assert_eq!(f[0].line, 3, "the un-allowed sibling still fires");
    let own = "fn f() {\n    // lint: allow(D1) — backoff timing only\n    let t = Instant::now();\n}\n";
    assert!(lint_source("rust/src/comm/transport/tcp.rs", own).is_empty());
}

#[test]
fn allow_hygiene_problems_are_l0_warnings() {
    let no_reason = "fn f() { let t = Instant::now(); } // lint: allow(D1)\n";
    let f = lint_source("rust/src/comm/transport/tcp.rs", no_reason);
    assert_eq!(fired(&f), vec![RuleId::L0], "{f:?}");
    assert_eq!(f[0].severity, Severity::Warn);
    let unknown = "fn f() {} // lint: allow(Z9) — no such rule\n";
    assert_eq!(fired(&lint_source("rust/src/comm/transport/tcp.rs", unknown)), vec![RuleId::L0]);
    let misplaced = "fn f() { g(); } // lint: hot-path\n";
    assert_eq!(fired(&lint_source("rust/src/comm/compress.rs", misplaced)), vec![RuleId::L0]);
}

// --- W1: the pinned wire surface -------------------------------------------

fn wire_files_with(root: &Path, mutate: impl Fn(&str, String) -> String) -> Vec<(String, String)> {
    WIRE_FILES
        .iter()
        .map(|rel| {
            let src = std::fs::read_to_string(root.join(rel)).expect("wire file readable");
            (rel.to_string(), mutate(rel, src))
        })
        .collect()
}

#[test]
fn renumbering_a_frame_kind_turns_the_lint_red() {
    let root = resolve_root(Path::new(env!("CARGO_MANIFEST_DIR"))).expect("repo root");
    let lock = std::fs::read_to_string(root.join("wire.lock")).expect("wire.lock is committed");

    // The shipped tree verifies against the committed lock.
    let live = extract_wire_surface(&wire_files_with(&root, |_, s| s)).expect("extracts");
    let clean = check_lock(&live, &lock);
    assert!(clean.is_empty(), "shipped tree drifted from wire.lock: {clean:?}");

    // Renumber Resume 10 → 11 in the source: exactly one W1 deny.
    let mutated = extract_wire_surface(&wire_files_with(&root, |rel, s| {
        if rel.ends_with("frame.rs") { s.replace("Resume = 10", "Resume = 11") } else { s }
    }))
    .expect("mutated tree still extracts");
    let f = check_lock(&mutated, &lock);
    assert_eq!(fired(&f), vec![RuleId::W1], "{f:?}");
    assert_eq!(f[0].severity, Severity::Deny);
    assert!(f[0].msg.contains("wire drift"), "{}", f[0].msg);
    assert!(f[0].msg.contains("FrameKind::Resume"), "{}", f[0].msg);
}

#[test]
fn deleting_a_pin_or_a_constant_is_also_red() {
    let root = resolve_root(Path::new(env!("CARGO_MANIFEST_DIR"))).expect("repo root");
    let live = extract_wire_surface(&wire_files_with(&root, |_, s| s)).expect("extracts");
    let lock = live.render();

    // A pin with no live constant behind it (stale lock) fires...
    let orphaned = format!("{lock}FrameKind::Gone = 99\n");
    assert_eq!(fired(&check_lock(&live, &orphaned)), vec![RuleId::W1]);

    // ...and so does a live constant nobody pinned (incomplete lock).
    let shrunk: String = lock
        .lines()
        .filter(|l| !l.starts_with("RETAINED_FRAMES"))
        .map(|l| format!("{l}\n"))
        .collect();
    let f = check_lock(&live, &shrunk);
    assert_eq!(fired(&f), vec![RuleId::W1], "{f:?}");
    assert!(f[0].msg.contains("not pinned"), "{}", f[0].msg);
}
