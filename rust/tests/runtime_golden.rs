//! Integration: execute every artifact via PJRT and compare against the
//! manifest goldens recorded by python at lowering time.
use zo_adam::runtime::{golden_tokens, golden_vec, HostTensor, Runtime};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn train_step_matches_golden() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    let rt = Runtime::new(&dir).unwrap();
    let names: Vec<String> = rt.manifest.models.keys().cloned().collect();
    for name in names {
        let model = rt.manifest.model(&name).unwrap().clone();
        if model.kind != "lm" { continue; }
        let exe = rt.load(&name, "train_step").unwrap();
        let params = rt.manifest.load_init(&name).unwrap();
        let batch = model.cfg("batch").unwrap();
        let seq = model.cfg("seq_len").unwrap();
        let vocab = model.cfg("vocab").unwrap();
        let tokens = golden_tokens(batch, seq, vocab);
        let d = params.len();
        let outs = exe.run(&[
            HostTensor::f32(params, &[d]),
            HostTensor::i32(tokens, &[batch, seq]),
        ]).unwrap();
        let golden = &exe.entry.golden;
        let loss = outs[0].scalar_f32().unwrap() as f64;
        assert!((loss - golden[0].head[0]).abs() < 1e-4 * golden[0].head[0].abs().max(1.0),
                "{name}: loss {loss} vs golden {}", golden[0].head[0]);
        let grads = outs[1].as_f32().unwrap();
        let norm = zo_adam::tensor::norm2(grads);
        assert!((norm - golden[1].norm).abs() < 1e-3 * golden[1].norm.max(1.0),
                "{name}: grad norm {norm} vs {}", golden[1].norm);
        println!("{name}: loss={loss:.5} grad_norm={norm:.5} OK");
    }
}

#[test]
fn pallas_kernels_match_golden() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    let rt = Runtime::new(&dir).unwrap();
    let names: Vec<String> = rt.manifest.models.keys().cloned().collect();
    let name = &names[0];
    let model = rt.manifest.model(name).unwrap().clone();
    let d = model.param_count;
    let g = golden_vec(d, 0.3, 0.1);
    let m = golden_vec(d, 1.1, 0.05);
    let x = golden_vec(d, 3.7, 1.0);
    let u = golden_vec(d, 4.9, 0.02);
    let v: Vec<f32> = golden_vec(d, 2.3, 0.2).iter().map(|a| a.abs() + 1e-3).collect();
    let rsv: Vec<f32> = v.iter().map(|vi| 1.0 / (vi + 1e-8f32).sqrt()).collect();
    let exe = rt.load(name, "zo_local_step").unwrap();
    let outs = exe.run(&[
        HostTensor::f32(vec![1e-3], &[1]),
        HostTensor::f32(g.clone(), &[d]),
        HostTensor::f32(m.clone(), &[d]),
        HostTensor::f32(x.clone(), &[d]),
        HostTensor::f32(u.clone(), &[d]),
        HostTensor::f32(rsv.clone(), &[d]),
    ]).unwrap();
    for (i, out) in outs.iter().enumerate() {
        let norm = zo_adam::tensor::norm2(out.as_f32().unwrap());
        let gn = exe.entry.golden[i].norm;
        assert!((norm - gn).abs() < 1e-3 * gn.max(1.0), "out {i}: {norm} vs {gn}");
    }
    println!("zo_local_step kernel OK (d={d})");

    let exe = rt.load(name, "ef_quantize").unwrap();
    let outs = exe.run(&[HostTensor::f32(g, &[d]), HostTensor::f32(m, &[d])]).unwrap();
    for (i, out) in outs.iter().enumerate() {
        let norm = zo_adam::tensor::norm2(out.as_f32().unwrap());
        let gn = exe.entry.golden[i].norm;
        assert!((norm - gn).abs() < 1e-3 * gn.max(1.0), "ef out {i}: {norm} vs {gn}");
    }
    println!("ef_quantize kernel OK");
}
