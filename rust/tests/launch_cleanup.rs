//! ISSUE 5 satellite: a failed `zo-adam launch` must never leak live
//! `zo-adam worker` processes. The original bug had two shapes: a
//! spawn error halfway through the worker loop `?`-propagated past the
//! reap loop entirely (ranks spawned so far were orphaned into their
//! 30 s handshake-retry window), and a root error only `wait()`ed —
//! blocking on, rather than terminating, stuck workers. `launch_tcp`
//! now owns every child through `coordinator::WorkerChildren`
//! (reap on success, grace-then-kill on root error, kill-on-drop as
//! the backstop); these tests drive the guard with real `zo-adam
//! worker` OS processes in exactly those states.

use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use zo_adam::coordinator::WorkerChildren;

fn exe() -> &'static str {
    env!("CARGO_BIN_EXE_zo-adam")
}

/// Is `pid` a live (or zombie-unreaped) process? The guard always
/// `wait()`s what it kills, so after it runs the pid must be fully
/// gone. (/proc check — these tests only assert liveness on Linux,
/// which is where CI runs; the guard logic itself is portable.)
fn alive(pid: u32) -> bool {
    std::path::Path::new(&format!("/proc/{pid}")).exists()
}

fn assert_dead(pid: u32, what: &str) {
    if cfg!(target_os = "linux") {
        assert!(!alive(pid), "{what}: pid {pid} still alive/unreaped");
    }
}

/// Spawn a worker that stays alive: it connects to `addr` (a listener
/// we bound but never accept/answer on), sends its Hello and then
/// blocks reading the ack under the transport's generous IO timeout —
/// the exact lingering process a leaked launch used to leave behind.
fn spawn_lingering_worker(children: &mut WorkerChildren, rank: usize, addr: &str) -> u32 {
    let child = Command::new(exe())
        .args(["worker", "--rank", &rank.to_string(), "--ranks", "4", "--connect", addr, "--quiet"])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn lingering worker");
    let pid = child.id();
    children.push(rank, child);
    pid
}

#[test]
fn dropped_guard_kills_spawned_workers() {
    // The mid-spawn-loop failure shape: children exist, an error
    // `?`-propagates, and the guard goes out of scope without any
    // explicit reap. Drop must kill + reap every child.
    let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut children = WorkerChildren::new();
    let pids: Vec<u32> =
        (1..3).map(|r| spawn_lingering_worker(&mut children, r, &addr)).collect();
    assert_eq!(children.len(), 2);
    for &pid in &pids {
        if cfg!(target_os = "linux") {
            assert!(alive(pid), "worker should be lingering before the drop");
        }
    }
    drop(children);
    for &pid in &pids {
        assert_dead(pid, "dropped guard");
    }
}

#[test]
fn shutdown_reaps_self_exits_and_kills_stragglers() {
    // The root-error shape: one worker already failed on its own (its
    // exit status is the diagnosis the launch error should carry) and
    // one is stuck in its handshake window. `shutdown` must report the
    // first and kill the second, within the grace bound.
    let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut children = WorkerChildren::new();

    // invalid rank ⇒ fast nonzero exit, no connection attempted
    let failing = Command::new(exe())
        .args(["worker", "--rank", "9", "--ranks", "4", "--connect", &addr, "--quiet"])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn failing worker");
    let failing_pid = failing.id();
    children.push(9, failing);
    let stuck_pid = spawn_lingering_worker(&mut children, 1, &addr);

    // give the failing worker ample time to exit on its own, so the
    // two classes in `notes` are deterministic
    std::thread::sleep(Duration::from_millis(1500));
    let t0 = Instant::now();
    let notes = children.shutdown(Duration::from_millis(200));
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "shutdown must be bounded by the grace period, not worker timeouts"
    );
    assert!(children.is_empty());
    assert_eq!(notes.len(), 2, "one self-exit + one kill: {notes:?}");
    assert!(
        notes.iter().any(|n| n.starts_with("rank 9 exited with")),
        "self-exit status must be reported: {notes:?}"
    );
    assert!(
        notes.iter().any(|n| n.starts_with("rank 1 killed")),
        "the stuck worker must be killed, not waited for: {notes:?}"
    );
    assert_dead(failing_pid, "self-exited worker");
    assert_dead(stuck_pid, "killed worker");
}

#[test]
fn reap_reports_failures_and_clean_exits() {
    let mut children = WorkerChildren::new();
    let ok = Command::new(exe())
        .args(["--help"])
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn help");
    children.push(1, ok);
    let bad = Command::new(exe())
        .args(["worker", "--rank", "9", "--ranks", "4", "--connect", "127.0.0.1:1", "--quiet"])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn failing worker");
    children.push(2, bad);
    let failures = children.reap();
    assert!(children.is_empty());
    assert_eq!(failures.len(), 1, "{failures:?}");
    assert!(failures[0].starts_with("rank 2 exited with"), "{failures:?}");
}

#[test]
fn launch_bind_conflict_fails_fast_without_spawning() {
    // The pre-spawn error path: the root's bind fails, so the launch
    // must exit promptly with a clear error (and there is nothing to
    // leak — the spawn loop never ran).
    let holder = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let port = holder.local_addr().unwrap().port();
    let t0 = Instant::now();
    let out = Command::new(exe())
        .args([
            "launch",
            "--ranks",
            "2",
            "--transport",
            "tcp",
            "--port",
            &port.to_string(),
            "--family",
            "adam",
            "--d",
            "64",
            "--steps",
            "2",
            "--quiet",
        ])
        .output()
        .expect("run launch");
    assert!(!out.status.success(), "bind conflict must fail the launch");
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "bind failure must not hang on handshake/worker timeouts"
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error"), "stderr should carry the error: {stderr}");
}
