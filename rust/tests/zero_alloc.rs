//! The zero-allocation hot-path invariant (ISSUE 2 tentpole,
//! DESIGN.md §Hot-path): after construction, `step_engine` performs no
//! heap allocation for any optimizer family — local steps, variance
//! rounds and 1-bit syncs included.
//!
//! Since ISSUE 3 the invariant holds in **both execution modes**: the
//! persistent pool replaced per-region scoped-thread spawning, so a
//! steady-state `ExecMode::Threaded` region is a publish–work–barrier
//! cycle on parked threads with no allocation anywhere in the process
//! (the counting allocator below is global, so pool workers are
//! counted too). The old "pool threads necessarily allocate spawn
//! bookkeeping" exemption is gone.
//!
//! Since ISSUE 9 the measurement runs with this thread's flight
//! recorder **armed**: the obs ring is preallocated at `arm` time and
//! every hook is an array store, so the invariant extends verbatim to
//! traced runs (the ring is sized to wrap mid-window, proving
//! overwrite-oldest allocates nothing either).
//!
//! This file holds a single test so no concurrent test can perturb the
//! global counter mid-measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a pure pass-through to the System allocator plus a relaxed
// counter bump — every GlobalAlloc obligation is delegated unchanged.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: same layout handed straight to System.alloc.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: same (ptr, layout) pair handed straight to System.dealloc.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: same arguments handed straight to System.realloc.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use zo_adam::coordinator::{Engine, ExecMode};
use zo_adam::optim::policy::{SyncPolicy, SyncSchedule, VarPolicy, VarSchedule};
use zo_adam::optim::{
    Adam, ConstLr, DistOptimizer, FrozenVarAdam, Hyper, MomentumSgd, NaiveOneBitAdam, SignSgd,
    ZeroOneAdam,
};
use zo_adam::tensor::Rng;

fn build_suite(d: usize, n: usize) -> Vec<(&'static str, Box<dyn DistOptimizer>)> {
    let h = Hyper::default();
    let lr = 0.01;
    let init = vec![0.8f32; d];
    let adam: Box<dyn DistOptimizer> =
        Box::new(Adam::new(init.clone(), n, h, Box::new(ConstLr(lr))));
    vec![
        ("adam", adam),
        ("momentum-sgd", Box::new(MomentumSgd::new(init.clone(), n, 0.9, Box::new(ConstLr(lr))))),
        ("signsgd-ef", Box::new(SignSgd::new(init.clone(), n, Box::new(ConstLr(lr))))),
        (
            "naive-1bit-adam",
            Box::new(NaiveOneBitAdam::new(init.clone(), n, h, Box::new(ConstLr(lr)))),
        ),
        (
            "1bit-adam",
            Box::new(FrozenVarAdam::onebit_adam(init.clone(), n, h, Box::new(ConstLr(lr)), 4)),
        ),
        (
            // Local steps + 1-bit syncs in the measured window.
            "01adam-local",
            Box::new(ZeroOneAdam::new(
                init.clone(),
                n,
                h,
                Box::new(ConstLr(lr)),
                VarSchedule::new(VarPolicy::Never),
                SyncSchedule::new(SyncPolicy::Fixed { interval: 3 }),
            )),
        ),
        (
            // Full-precision variance rounds + 1-bit syncs every step.
            "01adam-dense",
            Box::new(ZeroOneAdam::new(
                init,
                n,
                h,
                Box::new(ConstLr(lr)),
                VarSchedule::new(VarPolicy::Always),
                SyncSchedule::new(SyncPolicy::Always),
            )),
        ),
    ]
}

#[test]
fn steady_state_steps_allocate_nothing() {
    // d crosses two SERVER_CHUNKs and sits off the 64-bit words, so the
    // chunked EF server leg runs its multi-chunk path.
    let d = 4096 + 4096 + 137;
    let n = 3;
    let mut rng = Rng::new(42);
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 0.5);
            v
        })
        .collect();

    // Pool spawn allocations happen here — at construction, once.
    // Threaded(8) ≥ 2·n (n = 3 workers) also drives the lane-chunked
    // EF compress leg through its per-lane run_split regions.
    let engines = [
        ("seq", Engine::sequential()),
        ("threaded8", Engine::new(ExecMode::Threaded(8))),
    ];

    // Arm this thread's flight recorder (its single ring allocation
    // happens now, outside every measured window). 1024 events is far
    // fewer than the windows record, so the ring provably wraps inside
    // the measurement — overwrite-oldest must not allocate either.
    zo_adam::obs::arm(1024);

    for (ename, eng) in &engines {
        let mut opts = build_suite(d, n);
        for (name, opt) in opts.iter_mut() {
            // Warm-up: first steps may size internal codec buffers, and
            // pool threads may touch lazily-initialized TLS once.
            for t in 0..4u64 {
                opt.step_engine(t, &grads, eng);
            }
            let before = ALLOCS.load(Ordering::SeqCst);
            for t in 4..24u64 {
                opt.step_engine(t, &grads, eng);
            }
            let after = ALLOCS.load(Ordering::SeqCst);
            assert_eq!(
                after - before,
                0,
                "{ename}/{name}: {} allocation(s) in 20 steady-state steps (recorder armed)",
                after - before
            );
        }
    }

    // The windows above really were traced: the hooks fired, filled the
    // ring and wrapped it — all without a counted allocation.
    let rec = zo_adam::obs::disarm().expect("recorder still armed after measurement");
    assert_eq!(rec.len(), rec.capacity(), "ring filled during the measured windows");
    assert!(rec.dropped() > 0, "ring wrapped during the measured windows");
}
