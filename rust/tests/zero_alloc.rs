//! The zero-allocation hot-path invariant (ISSUE 2 tentpole,
//! DESIGN.md §Hot-path): after construction, `step_engine` performs no
//! heap allocation for any optimizer family — local steps, variance
//! rounds and 1-bit syncs included.
//!
//! Measured with a counting global allocator on the sequential engine
//! (pool threads necessarily allocate spawn bookkeeping, which is the
//! one documented exemption). This file holds a single test so no
//! concurrent test can perturb the global counter mid-measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use zo_adam::coordinator::Engine;
use zo_adam::optim::policy::{SyncPolicy, SyncSchedule, VarPolicy, VarSchedule};
use zo_adam::optim::{
    Adam, ConstLr, DistOptimizer, FrozenVarAdam, Hyper, MomentumSgd, NaiveOneBitAdam, SignSgd,
    ZeroOneAdam,
};
use zo_adam::tensor::Rng;

#[test]
fn steady_state_steps_allocate_nothing() {
    // d crosses two SERVER_CHUNKs and sits off the 64-bit words, so the
    // chunked EF server leg runs its multi-chunk path.
    let d = 4096 + 4096 + 137;
    let n = 3;
    let h = Hyper::default();
    let lr = 0.01;
    let mut rng = Rng::new(42);
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 0.5);
            v
        })
        .collect();
    let eng = Engine::sequential();
    let init = vec![0.8f32; d];

    let mut opts: Vec<(&'static str, Box<dyn DistOptimizer>)> = vec![
        ("adam", Box::new(Adam::new(init.clone(), n, h, Box::new(ConstLr(lr))))),
        ("momentum-sgd", Box::new(MomentumSgd::new(init.clone(), n, 0.9, Box::new(ConstLr(lr))))),
        ("signsgd-ef", Box::new(SignSgd::new(init.clone(), n, Box::new(ConstLr(lr))))),
        (
            "naive-1bit-adam",
            Box::new(NaiveOneBitAdam::new(init.clone(), n, h, Box::new(ConstLr(lr)))),
        ),
        (
            "1bit-adam",
            Box::new(FrozenVarAdam::onebit_adam(init.clone(), n, h, Box::new(ConstLr(lr)), 4)),
        ),
        (
            // Local steps + 1-bit syncs in the measured window.
            "01adam-local",
            Box::new(ZeroOneAdam::new(
                init.clone(),
                n,
                h,
                Box::new(ConstLr(lr)),
                VarSchedule::new(VarPolicy::Never),
                SyncSchedule::new(SyncPolicy::Fixed { interval: 3 }),
            )),
        ),
        (
            // Full-precision variance rounds + 1-bit syncs every step.
            "01adam-dense",
            Box::new(ZeroOneAdam::new(
                init,
                n,
                h,
                Box::new(ConstLr(lr)),
                VarSchedule::new(VarPolicy::Always),
                SyncSchedule::new(SyncPolicy::Always),
            )),
        ),
    ];

    for (name, opt) in opts.iter_mut() {
        // Warm-up: first steps may size internal codec buffers.
        for t in 0..4u64 {
            opt.step_engine(t, &grads, &eng);
        }
        let before = ALLOCS.load(Ordering::SeqCst);
        for t in 4..24u64 {
            opt.step_engine(t, &grads, &eng);
        }
        let after = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "{name}: {} allocation(s) in 20 steady-state steps",
            after - before
        );
    }
}
