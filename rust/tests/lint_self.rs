//! Self-application: the shipped tree lints clean under the CI
//! posture (`zo-adam lint --deny-all`), and the committed `wire.lock`
//! byte-matches what `--write-lock` would regenerate. This is the
//! ISSUE 8 acceptance gate running inside `cargo test`, so a PR that
//! reintroduces a banned idiom — or renumbers a frame kind without
//! regenerating the lock — fails before CI even reaches the lint step.

use std::path::Path;

use zo_adam::analysis::{resolve_root, run_tree, wire_surface_from_tree};

fn repo_root() -> std::path::PathBuf {
    resolve_root(Path::new(env!("CARGO_MANIFEST_DIR"))).expect("repo root above rust/")
}

#[test]
fn shipped_tree_lints_clean_under_deny_all() {
    let rep = run_tree(&repo_root(), true).expect("lint runs over the tree");
    assert!(
        rep.files_scanned > 20,
        "suspiciously small scan ({} files) — did the walk miss rust/src?",
        rep.files_scanned
    );
    let rendered: Vec<String> = rep.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        rep.findings.is_empty(),
        "the shipped tree must lint clean; findings:\n{}",
        rendered.join("\n")
    );
}

#[test]
fn committed_wire_lock_matches_the_live_surface() {
    let root = repo_root();
    let surface = wire_surface_from_tree(&root).expect("wire surface extracts");
    let lock = std::fs::read_to_string(root.join("wire.lock")).expect("wire.lock is committed");
    assert_eq!(
        lock,
        surface.render(),
        "wire.lock is stale — regenerate deliberately with `zo-adam lint --write-lock`"
    );
}
