//! ISSUE 10 acceptance: the checkpoint/resume contract end to end.
//!
//! * The run manifest round-trips through its JSON rendering and is
//!   self-verifying — any edited byte flips the self-digest and the
//!   load dies with a **typed** error, never a garbled resume.
//! * Shard corruption is caught twice: the shard's own trailing digest
//!   and the manifest's recorded cross-file digest.
//! * A resume into a different spec (seed, family, world, topology)
//!   dies typed at load, before any reduction traffic moves.
//! * The core guarantee: save at step k, tear the whole group down,
//!   resume in fresh processes — and the completed run is **bitwise
//!   identical** to an uninterrupted one under [`check_parity`], for
//!   plain Adam and 0/1 Adam, under star and tree schedules, over
//!   in-proc channels and real loopback TCP.

use zo_adam::comm::transport::tcp::Tcp;
use zo_adam::comm::transport::RankLink;
use zo_adam::comm::{Topology, SERVER_CHUNK};
use zo_adam::coordinator::{
    check_parity, launch_inproc_opts, run_local, run_rank_opts, DistSpec, ExecMode, RankOpts,
};
use zo_adam::runtime::checkpoint::{
    read_shard, shard_name, write_shard, CheckpointError, RunMeta, SHARD_HEADER_BYTES,
};
use zo_adam::runtime::manifest::{RunManifest, ShardEntry};

/// Fresh scratch directory under the OS temp dir; pid-stamped so
/// parallel test binaries never collide.
fn scratch(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("zo_ckpt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir.to_str().expect("utf8 temp path").to_string()
}

fn spec(family: &str, topology: Topology) -> DistSpec {
    DistSpec {
        family: family.to_string(),
        // spans two codec chunks off the 64-bit words: the chunked
        // server leg and ragged sign words both cross the cut point
        d: SERVER_CHUNK + 321,
        steps: 12,
        world: 4,
        seed: 7,
        lr: 0.01,
        kappa: 4.0,
        sigma: 0.15,
        init: 0.8,
        topology,
    }
}

fn meta(fingerprint: u64) -> RunMeta {
    RunMeta {
        fingerprint,
        family: "01adam".to_string(),
        d: 4417,
        steps: 12,
        world: 4,
        topology: "tree2".to_string(),
    }
}

// ---------------------------------------------------------------------
// Manifest golden round-trip
// ---------------------------------------------------------------------

#[test]
fn manifest_round_trips_and_is_self_verifying() {
    let shards = vec![
        ShardEntry { file: shard_name(0), bytes: 1234, digest: 0x0011_2233_4455_6677 },
        ShardEntry { file: shard_name(1), bytes: 1234, digest: 0x8899_aabb_ccdd_eeff },
    ];
    let man = RunManifest::new(10, meta(0xdead_beef_cafe_f00d), "per-rank", shards);
    let text = man.render();

    // Golden structure: versioned, hex-pinned u64s, self-digest last.
    assert!(text.contains("\"schema\""), "{text}");
    assert!(text.contains("0xdeadbeefcafef00d"), "{text}");
    assert!(text.contains("0x8899aabbccddeeff"), "{text}");
    assert!(text.trim_end().ends_with('}'), "{text}");

    let back = RunManifest::parse(&text).expect("round trip parses");
    assert_eq!(back, man);
    // Rendering is a pure function of the content: re-render is stable.
    assert_eq!(back.render(), text);

    // check() accepts its own metadata...
    back.check(&meta(0xdead_beef_cafe_f00d), "per-rank", 2).expect("self-check");
    // ...and rejects every mismatched field with the *named* error.
    let other = meta(0x1111_1111_1111_1111);
    assert!(matches!(
        back.check(&other, "per-rank", 2),
        Err(CheckpointError::SpecMismatch { .. })
    ));
    let mut fam = meta(0xdead_beef_cafe_f00d);
    fam.family = "adam".to_string();
    assert!(matches!(
        back.check(&fam, "per-rank", 2),
        Err(CheckpointError::FamilyMismatch { .. })
    ));
    assert!(matches!(
        back.check(&meta(0xdead_beef_cafe_f00d), "single", 1),
        Err(CheckpointError::LayoutMismatch { .. })
    ));
}

#[test]
fn edited_manifest_text_fails_typed() {
    let dir = scratch("manifest_edit");
    let info = write_shard(&dir, 0, 5, b"some optimizer state").expect("write shard");
    RunManifest::new(5, meta(0x42), "per-rank", vec![info.into()]).write(&dir).expect("write");

    let path = format!("{dir}/manifest.json");
    let text = std::fs::read_to_string(&path).expect("read manifest");

    // A one-token edit (layout string) flips the self-digest.
    std::fs::write(&path, text.replace("per-rank", "per-rankX")).expect("tamper");
    match RunManifest::load(&dir) {
        Err(CheckpointError::ManifestDigest { want, got }) => assert_ne!(want, got),
        other => panic!("want ManifestDigest, got {other:?}"),
    }

    // Outright garbage is a typed Manifest error, not a panic.
    std::fs::write(&path, "not json at all").expect("garbage");
    assert!(matches!(
        RunManifest::load(&dir),
        Err(CheckpointError::Manifest { .. })
    ));

    // A directory with no manifest says so.
    std::fs::remove_file(&path).expect("rm manifest");
    match RunManifest::load(&dir) {
        Err(CheckpointError::Manifest { detail }) => {
            assert!(detail.contains("not found"), "{detail}");
        }
        other => panic!("want Manifest(not found), got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Shard corruption
// ---------------------------------------------------------------------

#[test]
fn flipped_shard_byte_fails_typed_at_both_layers() {
    let dir = scratch("shard_flip");
    let body: Vec<u8> = (0..257u32).map(|i| (i * 7) as u8).collect();
    let info = write_shard(&dir, 0, 9, &body).expect("write shard");

    // Pristine file reads back exactly.
    let (step, got) = read_shard(&dir, 0, Some(info.digest)).expect("clean read");
    assert_eq!(step, 9);
    assert_eq!(got, body);

    // Flip one bit inside the state body.
    let path = format!("{}/{}", dir, shard_name(0));
    let mut bytes = std::fs::read(&path).expect("read file");
    bytes[SHARD_HEADER_BYTES + 42] ^= 0x04;
    std::fs::write(&path, &bytes).expect("corrupt");

    // Layer 1: the manifest's recorded digest for the shard.
    match read_shard(&dir, 0, Some(info.digest)) {
        Err(CheckpointError::ShardDigestMismatch { shard, want, got }) => {
            assert_eq!(shard, shard_name(0));
            assert_eq!(want, info.digest);
            assert_ne!(want, got);
        }
        other => panic!("want ShardDigestMismatch, got {other:?}"),
    }
    // Layer 2: the shard's own trailing digest, with no manifest at all.
    assert!(matches!(
        read_shard(&dir, 0, None),
        Err(CheckpointError::DigestMismatch { .. })
    ));

    // Truncation and a stomped magic are their own errors.
    std::fs::write(&path, &bytes[..SHARD_HEADER_BYTES - 1]).expect("truncate");
    assert!(matches!(
        read_shard(&dir, 0, None),
        Err(CheckpointError::Truncated { .. })
    ));
    bytes[0] ^= 0xFF;
    std::fs::write(&path, &bytes).expect("bad magic");
    assert!(matches!(
        read_shard(&dir, 0, None),
        Err(CheckpointError::BadMagic { .. })
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Mismatched resume dies typed at load
// ---------------------------------------------------------------------

#[test]
fn mismatched_spec_resume_dies_typed_before_traffic() {
    let dir = scratch("mismatch");
    let sp = spec("01adam", Topology::Star);
    let save = RankOpts {
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 5,
        ..Default::default()
    };
    launch_inproc_opts(&sp, &save).expect("save run");

    let resume = RankOpts { resume: Some(dir.clone()), ..Default::default() };
    let expect_typed = |other: &DistSpec, needle: &str| {
        let err = match launch_inproc_opts(other, &resume) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("{needle}: mismatched resume unexpectedly succeeded"),
        };
        assert!(err.contains("checkpoint error"), "{needle}: {err}");
        assert!(err.contains(needle), "{err}");
    };
    // Same shape, different data seed → fingerprint gate.
    expect_typed(&DistSpec { seed: sp.seed + 1, ..sp.clone() }, "fingerprint mismatch");
    // Different optimizer family → named before the fingerprint diff.
    expect_typed(&DistSpec { family: "adam".to_string(), ..sp.clone() }, "family mismatch");
    // Different reduction schedule.
    expect_typed(
        &DistSpec { topology: Topology::Tree { group: 2 }, ..sp.clone() },
        "topology mismatch",
    );
    // Different world size.
    expect_typed(&DistSpec { world: 3, ..sp.clone() }, "world size mismatch");

    // The matching spec still resumes fine after all those rejections.
    launch_inproc_opts(&sp, &resume).expect("matching spec resumes");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Bitwise resume parity
// ---------------------------------------------------------------------

/// Save-at-10, resume-in-fresh-group, and the completed run must be
/// bitwise the uninterrupted single-process reference: parameters,
/// every per-step mean loss (restored prefix + resumed tail), final
/// eval, and the ledger's round counts.
#[test]
fn inproc_resume_is_bitwise_for_star_and_tree() {
    for family in ["adam", "01adam"] {
        for topo in [Topology::Star, Topology::Tree { group: 2 }] {
            let dir = scratch(&format!("parity_{family}_{topo}"));
            let sp = spec(family, topo);
            let save = RankOpts {
                checkpoint_dir: Some(dir.clone()),
                checkpoint_every: 5, // cuts at 5 and 10; manifest ends at 10
                ..Default::default()
            };
            let full = launch_inproc_opts(&sp, &save)
                .unwrap_or_else(|e| panic!("{family}/{topo} save run: {e}"));

            // Fresh transports, fresh optimizers: steps 10..12 re-run
            // from restored state (EF memory, RNG streams, ledger).
            let resume = RankOpts { resume: Some(dir.clone()), ..Default::default() };
            let resumed = launch_inproc_opts(&sp, &resume)
                .unwrap_or_else(|e| panic!("{family}/{topo} resume run: {e}"));

            let local = run_local(&sp, ExecMode::Sequential);
            check_parity(&resumed[0], &local)
                .unwrap_or_else(|e| panic!("{family}/{topo} resumed vs local: {e}"));

            // And the resumed run is bitwise the uninterrupted
            // *distributed* run too — checkpointing never feeds back.
            for (a, b) in resumed[0].final_params.iter().zip(&full[0].final_params) {
                assert_eq!(a.to_bits(), b.to_bits(), "{family}/{topo}");
            }
            assert_eq!(resumed[0].losses.len(), full[0].losses.len(), "{family}/{topo}");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn tcp_resume_is_bitwise_threaded4() {
    let dir = scratch("tcp_parity");
    let sp = spec("01adam", Topology::Star);

    let run_group = |opts: &RankOpts| {
        let group = Tcp::loopback_group(sp.world, sp.fingerprint())
            .unwrap_or_else(|e| panic!("loopback group: {e}"));
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = group
                .into_iter()
                .map(|tp| {
                    let sp = &sp;
                    s.spawn(move || {
                        let mut link = RankLink::new(Box::new(tp));
                        run_rank_opts(&mut link, sp, opts)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread").unwrap_or_else(|e| panic!("{e}")))
                .collect()
        });
        results
    };

    // First life: real sockets, checkpoints at 5 and 10, then the
    // whole group (sockets included) is torn down.
    let save = RankOpts {
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 5,
        ..Default::default()
    };
    run_group(&save);

    // Second life: brand-new sockets resume 10..12 from disk.
    let resume = RankOpts { resume: Some(dir.clone()), ..Default::default() };
    let results = run_group(&resume);

    let local = run_local(&sp, ExecMode::Threaded(4));
    check_parity(&results[0], &local).unwrap_or_else(|e| panic!("tcp resumed vs local: {e}"));
    let _ = std::fs::remove_dir_all(&dir);
}
