//! ISSUE 7 acceptance: the chaos scenario matrix. Every
//! (fault × topology × family) cell must land on one half of the
//! tripartite contract — **transparent recovery** with bit-for-bit
//! parity against the clean in-process reference, or a **typed
//! error** on every stranded rank within its deadline — and no cell
//! may hang past its bounds. The fault plans are seeded and
//! deterministic (`comm::transport::chaos`), so these are ordinary
//! reproducible tests, not flake roulette.
//!
//! Also here: the `--connect-timeout` regression (a never-answering
//! address fails typed within the window, ISSUE 7 satellite) and the
//! dead-rank survivor bound (a rank dying mid-round strands the
//! others for at most one deadline + resume window each).

use std::time::{Duration, Instant};

use zo_adam::comm::transport::tcp::{Tcp, TcpOpts};
use zo_adam::comm::transport::{RankLink, Scenario, TransportError};
use zo_adam::comm::{Topology, SERVER_CHUNK};
use zo_adam::coordinator::{run_cell, run_rank, CellOutcome, ChaosOpts, DistSpec};

fn spec(family: &str, topo: Topology, world: usize) -> DistSpec {
    DistSpec {
        family: family.to_string(),
        // spans a codec chunk boundary off the 64-bit words, so
        // resumed rounds replay the ragged multi-chunk wire path
        d: SERVER_CHUNK + 321,
        steps: 10,
        world,
        seed: 7,
        lr: 0.01,
        kappa: 4.0,
        sigma: 0.15,
        init: 0.8,
        topology: topo,
        ..DistSpec::default()
    }
}

/// Tight-but-safe deadlines: big enough that a healthy loopback cell
/// never trips them, small enough that a stuck cell fails the suite
/// in seconds instead of minutes.
fn opts() -> ChaosOpts {
    ChaosOpts {
        seed: 7,
        connect_timeout: Duration::from_secs(5),
        recv_deadline: Duration::from_secs(3),
        resume_window: Duration::from_secs(2),
    }
}

const TOPOLOGIES: [Topology; 2] = [Topology::Star, Topology::Tree { group: 2 }];

/// One matrix slice per family (separate #[test]s so the harness runs
/// them concurrently): every recovery scenario must complete with the
/// exact bits of the uninterrupted run, and the severing scenarios
/// must prove they actually exercised reconnect-with-resume.
fn recovery_slice(family: &str) {
    for topo in TOPOLOGIES {
        for sc in [Scenario::Straggler, Scenario::Jitter, Scenario::Drop, Scenario::Truncate] {
            let spec = spec(family, topo, 5);
            let report = run_cell(&spec, sc, &opts(), true)
                .unwrap_or_else(|e| panic!("{family}/{topo}/{}: bootstrap: {e}", sc.name()));
            report
                .satisfies_contract()
                .unwrap_or_else(|e| panic!("{family}/{topo}/{}: {e}", sc.name()));
            assert_eq!(
                report.outcome,
                CellOutcome::Recovered,
                "{family}/{topo}/{}",
                sc.name()
            );
            assert!(
                matches!(report.parity, Some(Ok(()))),
                "{family}/{topo}/{}: parity missing or broken",
                sc.name()
            );
            if sc.expects_resumes() {
                assert!(
                    report.resumes > 0,
                    "{family}/{topo}/{}: plan severed nothing",
                    sc.name()
                );
            }
        }
    }
}

#[test]
fn recovery_scenarios_are_bitwise_transparent_01adam() {
    recovery_slice("01adam");
}

#[test]
fn recovery_scenarios_are_bitwise_transparent_adam() {
    recovery_slice("adam");
}

#[test]
fn fail_fast_scenarios_error_typed_within_the_deadline() {
    // Corruption and replay are unrecoverable by design (DESIGN.md
    // §Fault model): every cell must end in typed errors — and do so
    // within the deadline budget, because a misdelivered frame must
    // strand no rank in a silent block. One family suffices: the
    // fault fires in the shared frame layer, below the optimizers.
    for topo in TOPOLOGIES {
        for sc in [Scenario::Corrupt, Scenario::Duplicate] {
            let t0 = Instant::now();
            let spec = spec("01adam", topo, 5);
            let report = run_cell(&spec, sc, &opts(), false)
                .unwrap_or_else(|e| panic!("{topo}/{}: bootstrap: {e}", sc.name()));
            let elapsed = t0.elapsed();
            report
                .satisfies_contract()
                .unwrap_or_else(|e| panic!("{topo}/{}: {e}", sc.name()));
            assert_eq!(report.outcome, CellOutcome::Failed, "{topo}/{}", sc.name());
            assert!(!report.errors.is_empty());
            // Every stranded rank waits at most ~one recv deadline,
            // plus a failed resume window for those that try; 20 s is
            // several times that worst case on a healthy host.
            assert!(
                elapsed < Duration::from_secs(20),
                "{topo}/{}: cell took {elapsed:?} — a hidden stall",
                sc.name()
            );
        }
    }
}

#[test]
fn same_seed_same_faults_same_resume_count() {
    // Determinism end to end: two runs of the same severing cell must
    // drop the same frames and therefore resume the same number of
    // times — and both reproduce the reference bits.
    let cell = || {
        run_cell(&spec("01adam", Topology::Star, 3), Scenario::Drop, &opts(), true)
            .expect("bootstrap")
    };
    let (a, b) = (cell(), cell());
    assert_eq!(a.outcome, CellOutcome::Recovered);
    assert_eq!(b.outcome, CellOutcome::Recovered);
    assert!(a.resumes > 0);
    assert_eq!(a.resumes, b.resumes, "seeded fault plans must replay identically");
    assert!(matches!(a.parity, Some(Ok(()))));
    assert!(matches!(b.parity, Some(Ok(()))));
}

#[test]
fn never_answering_address_fails_within_the_connect_window() {
    // ISSUE 7 satellite: the worker dial window is configurable and
    // *bounded*. Bind a port, then close it — every retry gets
    // connection-refused, and the backoff loop must give up with a
    // typed Timeout once the window elapses (not the old fixed 30 s).
    let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    let opts = TcpOpts { connect_timeout: Duration::from_secs(1), ..TcpOpts::default() };
    let t0 = Instant::now();
    let err = Tcp::connect_topo_opts(&addr, 1, 2, 0xfee1, Topology::Star, &opts)
        .expect_err("nothing is listening");
    let elapsed = t0.elapsed();
    assert!(
        matches!(err, TransportError::Timeout { peer: 0, .. }),
        "want a typed dial timeout naming the root, got: {err}"
    );
    assert!(elapsed >= Duration::from_millis(500), "gave up before the window: {elapsed:?}");
    assert!(elapsed < Duration::from_secs(10), "overshot the 1 s window: {elapsed:?}");
}

#[test]
fn dead_rank_mid_round_bounds_survivor_exit() {
    // ISSUE 7 satellite (in-process half; tests/chaos_shutdown.rs
    // kills a real OS process): rank 2 vanishes before its first
    // round. Each survivor must exit with a typed error within about
    // one recv deadline plus one failed resume window — never hang on
    // the hole in the group.
    let spec = spec("01adam", Topology::Star, 3);
    let opts = TcpOpts {
        connect_timeout: Duration::from_secs(5),
        recv_deadline: Duration::from_secs(2),
        resume_window: Duration::from_secs(1),
        max_resumes: 2,
    };
    let mut group =
        Tcp::loopback_group_opts(3, spec.fingerprint(), Topology::Star, &opts).unwrap();
    let dead = group.pop().expect("rank 2");
    drop(dead); // the mid-run death: sockets close, rank 2 is gone
    let t0 = Instant::now();
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = group
            .into_iter()
            .map(|tp| {
                let spec = &spec;
                s.spawn(move || {
                    let mut link = RankLink::new(Box::new(tp));
                    run_rank(&mut link, spec)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
    });
    let elapsed = t0.elapsed();
    for (rank, res) in results.into_iter().enumerate() {
        let err = res.err().unwrap_or_else(|| panic!("rank {rank} should have failed"));
        assert!(
            matches!(
                err,
                TransportError::Closed { .. }
                    | TransportError::Truncated { .. }
                    | TransportError::Timeout { .. }
                    | TransportError::Io(_)
            ),
            "rank {rank}: want a link-death error, got: {err}"
        );
    }
    assert!(
        elapsed < Duration::from_secs(15),
        "survivors took {elapsed:?} to notice a dead rank — the bound is broken"
    );
}
