//! Cross-layer parity: the L1 Pallas kernels (executed via PJRT) must
//! agree with the L3 native step engine on random inputs — the native
//! loops in `optim/` are trusted because these tests pin them to the
//! lowered kernels, which are themselves pinned to `ref.py` by pytest.

use zo_adam::runtime::{HostTensor, Runtime};
use zo_adam::tensor::Rng;

fn artifacts() -> Option<Runtime> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json")
        .exists()
        .then(|| Runtime::new(&dir).unwrap())
}

fn rand_vec(rng: &mut Rng, d: usize, sigma: f32) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, sigma);
    v
}

#[test]
fn zo_local_step_kernel_matches_native() {
    let Some(rt) = artifacts() else { return };
    let model = rt.manifest.models.keys().next().unwrap().clone();
    let d = rt.manifest.model(&model).unwrap().param_count;
    let beta1 = rt.manifest.beta1 as f32;
    let exe = rt.load(&model, "zo_local_step").unwrap();

    let mut rng = Rng::new(11);
    for trial in 0..3 {
        let g = rand_vec(&mut rng, d, 0.5);
        let m = rand_vec(&mut rng, d, 0.2);
        let x = rand_vec(&mut rng, d, 1.0);
        let u = rand_vec(&mut rng, d, 0.1);
        let rsv: Vec<f32> = rand_vec(&mut rng, d, 1.0)
            .iter()
            .map(|v| 1.0 / (v.abs() + 1e-2).sqrt())
            .collect();
        let gamma = 1e-3f32 * (trial + 1) as f32;

        let outs = exe
            .run(&[
                HostTensor::f32(vec![gamma], &[1]),
                HostTensor::f32(g.clone(), &[d]),
                HostTensor::f32(m.clone(), &[d]),
                HostTensor::f32(x.clone(), &[d]),
                HostTensor::f32(u.clone(), &[d]),
                HostTensor::f32(rsv.clone(), &[d]),
            ])
            .unwrap();
        let (km, kx, ku) = (
            outs[0].as_f32().unwrap(),
            outs[1].as_f32().unwrap(),
            outs[2].as_f32().unwrap(),
        );
        for i in (0..d).step_by(97) {
            let m_new = beta1 * m[i] + (1.0 - beta1) * g[i];
            let step = gamma * m_new;
            assert!((km[i] - m_new).abs() <= 1e-5, "m[{i}]");
            assert!((kx[i] - (x[i] - step * rsv[i])).abs() <= 1e-4, "x[{i}]");
            assert!((ku[i] - (u[i] + step)).abs() <= 1e-5, "u[{i}]");
        }
    }
}

#[test]
fn ef_quantize_kernel_matches_rust_codec() {
    // The device-side quantizer and the Rust wire codec must agree on
    // every sign and on the shared scale.
    let Some(rt) = artifacts() else { return };
    let model = rt.manifest.models.keys().next().unwrap().clone();
    let d = rt.manifest.model(&model).unwrap().param_count;
    let exe = rt.load(&model, "ef_quantize").unwrap();

    let mut rng = Rng::new(13);
    let z = rand_vec(&mut rng, d, 1.0);
    let e = rand_vec(&mut rng, d, 0.3);
    let outs = exe
        .run(&[HostTensor::f32(z.clone(), &[d]), HostTensor::f32(e.clone(), &[d])])
        .unwrap();
    let q = outs[0].as_f32().unwrap();
    let scale_kernel = outs[2].as_f32().unwrap()[0];

    // Rust codec on s = z + e.
    let s: Vec<f32> = z.iter().zip(&e).map(|(a, b)| a + b).collect();
    let packed = zo_adam::comm::compress(&s);
    assert!(
        (packed.scale - scale_kernel).abs() <= 2e-5 * scale_kernel.abs().max(1.0),
        "scale: rust {} vs kernel {}",
        packed.scale,
        scale_kernel
    );
    let mut dense = vec![0.0f32; d];
    zo_adam::comm::decompress_into(&packed, &mut dense);
    let mut sign_mismatches = 0usize;
    for i in 0..d {
        if (dense[i] >= 0.0) != (q[i] >= 0.0) {
            // only legitimate at s[i] == 0 boundary / fp noise
            if s[i].abs() > 1e-6 {
                sign_mismatches += 1;
            }
        }
    }
    assert_eq!(sign_mismatches, 0);
}

#[test]
fn adam_step_kernel_matches_native_adam_update() {
    let Some(rt) = artifacts() else { return };
    let model = rt.manifest.models.keys().next().unwrap().clone();
    let d = rt.manifest.model(&model).unwrap().param_count;
    let (b1, b2, eps) = (
        rt.manifest.beta1 as f32,
        rt.manifest.beta2 as f32,
        rt.manifest.eps as f32,
    );
    let exe = rt.load(&model, "adam_step").unwrap();

    let mut rng = Rng::new(17);
    let g = rand_vec(&mut rng, d, 0.5);
    let m = rand_vec(&mut rng, d, 0.2);
    let v: Vec<f32> = rand_vec(&mut rng, d, 0.3).iter().map(|a| a * a).collect();
    let x = rand_vec(&mut rng, d, 1.0);
    let gamma = 3e-4f32;
    let outs = exe
        .run(&[
            HostTensor::f32(vec![gamma], &[1]),
            HostTensor::f32(g.clone(), &[d]),
            HostTensor::f32(m.clone(), &[d]),
            HostTensor::f32(v.clone(), &[d]),
            HostTensor::f32(x.clone(), &[d]),
        ])
        .unwrap();
    let (km, kv, kx) = (
        outs[0].as_f32().unwrap(),
        outs[1].as_f32().unwrap(),
        outs[2].as_f32().unwrap(),
    );
    for i in (0..d).step_by(101) {
        let m_new = b1 * m[i] + (1.0 - b1) * g[i];
        let v_new = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        let x_new = x[i] - gamma * m_new / (v_new + eps).sqrt();
        assert!((km[i] - m_new).abs() <= 1e-5);
        assert!((kv[i] - v_new).abs() <= 1e-5);
        assert!((kx[i] - x_new).abs() <= 1e-4, "x[{i}]: {} vs {}", kx[i], x_new);
    }
}

#[test]
fn zo_sync_step_kernel_matches_native() {
    let Some(rt) = artifacts() else { return };
    let model = rt.manifest.models.keys().next().unwrap().clone();
    let d = rt.manifest.model(&model).unwrap().param_count;
    let exe = rt.load(&model, "zo_sync_step").unwrap();

    let mut rng = Rng::new(19);
    let xa = rand_vec(&mut rng, d, 1.0);
    let ub = rand_vec(&mut rng, d, 0.05);
    let rsv: Vec<f32> = rand_vec(&mut rng, d, 1.0)
        .iter()
        .map(|v| 1.0 / (v.abs() + 1e-2).sqrt())
        .collect();
    let gsum = 4e-3f32;
    let outs = exe
        .run(&[
            HostTensor::f32(vec![gsum], &[1]),
            HostTensor::f32(xa.clone(), &[d]),
            HostTensor::f32(ub.clone(), &[d]),
            HostTensor::f32(rsv.clone(), &[d]),
        ])
        .unwrap();
    let (km, kx) = (outs[0].as_f32().unwrap(), outs[1].as_f32().unwrap());
    for i in (0..d).step_by(89) {
        assert!((km[i] - ub[i] / gsum).abs() <= 1e-3 * (ub[i] / gsum).abs().max(1.0));
        assert!((kx[i] - (xa[i] - ub[i] * rsv[i])).abs() <= 1e-4);
    }
}
