//! ISSUE 6 acceptance: the two-level tree schedule is **bitwise equal
//! across deployments** — the in-proc framed transport, real loopback
//! TCP, and the single-process engine reference all running the same
//! `(world, g)` tree produce identical parameters, per-step losses and
//! ledger round counts, for every optimizer family and for ragged /
//! singleton / degenerate group shapes.
//!
//! What the tree is NOT: bitwise equal to the star for g < n. f32
//! addition is not associative and the leaders re-compress their
//! subtree partial, so the tree is its own (equally valid) trajectory;
//! `tree{g >= n}` however *normalizes* to the star and must match it
//! byte for byte, ledger included. Both directions are pinned here.

use zo_adam::comm::transport::tcp::Tcp;
use zo_adam::comm::transport::RankLink;
use zo_adam::comm::{onebit_payload_bytes, Topology, HEADER_BYTES, SERVER_CHUNK};
use zo_adam::coordinator::distributed::FAMILIES;
use zo_adam::coordinator::{check_parity, launch_inproc, run_local, run_rank, DistSpec, ExecMode};

fn spec(family: &str, d: usize, steps: u64, world: usize, topology: Topology) -> DistSpec {
    DistSpec {
        family: family.to_string(),
        d,
        steps,
        world,
        seed: 11,
        topology,
        ..DistSpec::default()
    }
}

#[test]
fn nine_tree3_inproc_ranks_match_the_tree_scheduled_engine_for_every_family() {
    // d spans two codec chunks and sits off the 64-bit words; 12 steps
    // cross 1-bit Adam's T0 and several 0/1 Adam syncs; 9 ranks in
    // groups of 3 exercise the full leader/member/root role split.
    let d = 2 * SERVER_CHUNK + 777;
    let topo = Topology::Tree { group: 3 };
    for family in FAMILIES {
        let spec = spec(family, d, 12, 9, topo);
        let results = launch_inproc(&spec).unwrap_or_else(|e| panic!("{family}: {e}"));
        let local = run_local(&spec, ExecMode::with_threads(9));
        check_parity(&results[0], &local).unwrap_or_else(|e| panic!("{family}: {e}"));
        // every rank counted the same rounds (bytes differ by role:
        // the root and relaying leaders move more frames than members)
        for r in &results[1..] {
            assert_eq!(
                (r.ledger.fp_rounds, r.ledger.onebit_rounds, r.ledger.skipped_steps),
                (
                    results[0].ledger.fp_rounds,
                    results[0].ledger.onebit_rounds,
                    results[0].ledger.skipped_steps
                ),
                "{family} rank {}",
                r.rank
            );
        }
    }
}

#[test]
fn tree_shape_sweep_matches_the_engine_bitwise() {
    // World sizes straddling group boundaries × group sizes including
    // g ≈ √n: full groups (9/3), ragged last groups (8/3, 16/3),
    // singleton last groups (9/4, 3/2, 9/2) all run the same schedule
    // on the transport and in the engine.
    let d = SERVER_CHUNK + 321;
    for &world in &[3usize, 4, 8, 9, 16] {
        let isq = ((world as f64).sqrt().round() as usize).max(2);
        let mut gs = vec![2usize, 3, 4, isq];
        gs.sort_unstable();
        gs.dedup();
        for g in gs {
            if g >= world {
                continue; // degenerate — pinned by the star-collapse test
            }
            let spec = spec("01adam", d, 8, world, Topology::Tree { group: g });
            let results =
                launch_inproc(&spec).unwrap_or_else(|e| panic!("n={world} g={g}: {e}"));
            let local = run_local(&spec, ExecMode::with_threads(world));
            check_parity(&results[0], &local).unwrap_or_else(|e| panic!("n={world} g={g}: {e}"));
        }
    }
}

#[test]
fn nine_tcp_tree3_ranks_match_the_engine() {
    // Real loopback sockets, including the leader member-listener
    // bootstrap, for the families with the richest comm schedules.
    let topo = Topology::Tree { group: 3 };
    for family in ["01adam", "1bit-adam"] {
        let spec = spec(family, SERVER_CHUNK + 321, 8, 9, topo);
        let group = Tcp::loopback_group_topo(9, spec.fingerprint(), topo)
            .unwrap_or_else(|e| panic!("{family}: loopback group: {e}"));
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = group
                .into_iter()
                .map(|tp| {
                    let spec = &spec;
                    s.spawn(move || {
                        let mut link = RankLink::new(Box::new(tp));
                        run_rank(&mut link, spec)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().expect("rank thread").unwrap_or_else(|e| panic!("{family}: {e}"))
                })
                .collect()
        });
        let local = run_local(&spec, ExecMode::with_threads(9));
        check_parity(&results[0], &local)
            .unwrap_or_else(|e| panic!("{family} over tcp tree3: {e}"));
    }
}

#[test]
fn oversized_group_collapses_to_the_star_bitwise() {
    // tree{g >= n} normalizes to the star *schedule* — not just the
    // same answer, the same code path. Params, losses and the ledger's
    // exact framed bytes must all match, and the handshake fingerprint
    // must agree so either spelling can join the same launch.
    let d = SERVER_CHUNK + 9;
    for family in ["01adam", "1bit-adam"] {
        let tree = spec(family, d, 8, 4, Topology::Tree { group: 9 });
        let star = spec(family, d, 8, 4, Topology::Star);
        assert_eq!(tree.fingerprint(), star.fingerprint(), "{family}");
        let a = launch_inproc(&tree).unwrap();
        let b = launch_inproc(&star).unwrap();
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.ledger.bytes_total, rb.ledger.bytes_total, "{family} rank {}", ra.rank);
        }
        for (j, (x, y)) in a[0].final_params.iter().zip(&b[0].final_params).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{family} param {j}");
        }
        assert_eq!(a[0].losses, b[0].losses, "{family}");
    }
}

#[test]
fn a_real_tree_is_its_own_trajectory_not_the_star() {
    // The impossibility argument, pinned as a test: f32 addition is
    // not associative and leaders re-compress, so tree3 over 9 ranks
    // CANNOT be the star's bits — if it ever is, the tree schedule has
    // silently stopped running and the whole suite above is vacuous.
    let tree = spec("01adam", SERVER_CHUNK + 321, 8, 9, Topology::Tree { group: 3 });
    let star = spec("01adam", SERVER_CHUNK + 321, 8, 9, Topology::Star);
    let a = launch_inproc(&tree).unwrap();
    let b = launch_inproc(&star).unwrap();
    assert!(
        a[0].final_params
            .iter()
            .zip(&b[0].final_params)
            .any(|(x, y)| x.to_bits() != y.to_bits()),
        "tree3 and star produced identical bits — is the tree schedule actually running?"
    );
}

#[test]
fn tree_ledger_counts_exact_per_role_framed_bytes() {
    // 5 ranks in groups of 2: {0,1} {2,3} {4}. Per round each rank
    // moves k_r frames in each direction — root: (g0−1)+(G−1) = 3;
    // group-0 member: 1; relaying leader 2: its group size 2; member
    // 3: 1; singleton leader 4: 1 (its "partial" is its own upload).
    let d = 1500;
    let spec = spec("01adam-nolocal", d, 6, 5, Topology::Tree { group: 2 });
    let results = launch_inproc(&spec).unwrap();
    let fp_frame = (HEADER_BYTES + 2 * d) as u64; // fp16 payload
    let ef_frame = (HEADER_BYTES + onebit_payload_bytes(d)) as u64;
    let k = [3u64, 1, 2, 1, 1];
    for (r, want_k) in results.iter().zip(k) {
        let want = r.ledger.fp_rounds * 2 * want_k * fp_frame
            + r.ledger.onebit_rounds * 2 * want_k * ef_frame;
        assert_eq!(
            r.ledger.bytes_total, want,
            "rank {}: framed-byte accounting must be exact per role",
            r.rank
        );
    }
    for r in &results[1..] {
        assert_eq!(
            (r.ledger.fp_rounds, r.ledger.onebit_rounds),
            (results[0].ledger.fp_rounds, results[0].ledger.onebit_rounds),
            "rank {}",
            r.rank
        );
    }
}

#[test]
fn tree_root_combine_ingress_is_leader_partials_only() {
    // The acceptance ratio, measured on the wire: after R direct EF
    // rounds the root's combine-level ingress — bytes from the peers
    // whose uploads its root leg combines — is (G−1) EfPartial frames
    // per round under tree3 vs (n−1) Ef uploads under the star:
    // (⌈9/3⌉−1)/(9−1) = 1/4 of the star's fan-in.
    use zo_adam::comm::transport::inproc;
    use zo_adam::comm::EfAllReduce;
    use zo_adam::tensor::Rng;

    let d = SERVER_CHUNK + 77;
    let world = 9usize;
    let rounds = 3u64;
    let ef_frame = (HEADER_BYTES + onebit_payload_bytes(d)) as u64;

    let run = |topo: Topology| -> (u64, u64) {
        let mut links: Vec<RankLink> = inproc::group_topo(world, topo)
            .into_iter()
            .map(|tp| {
                let mut link = RankLink::new(Box::new(tp));
                link.set_topology(topo);
                link
            })
            .collect();
        let workers: Vec<_> = links
            .drain(1..)
            .enumerate()
            .map(|(i, mut link)| {
                let rank = i + 1;
                std::thread::spawn(move || {
                    let mut ef = EfAllReduce::new(1, d);
                    let mut out = vec![0.0f32; d];
                    for round in 0..rounds {
                        let mut rng = Rng::new(100 + round * 32 + rank as u64);
                        let mut buf = vec![0.0f32; d];
                        rng.fill_normal(&mut buf, 1.0);
                        let bufs = vec![buf];
                        ef.reduce_transport(&bufs, &mut out, &mut link).unwrap();
                    }
                })
            })
            .collect();
        let mut root = links.pop().expect("rank 0");
        let mut ef = EfAllReduce::new(1, d);
        let mut out = vec![0.0f32; d];
        for round in 0..rounds {
            let mut rng = Rng::new(100 + round * 32);
            let mut buf = vec![0.0f32; d];
            rng.fill_normal(&mut buf, 1.0);
            let bufs = vec![buf];
            ef.reduce_transport(&bufs, &mut out, &mut root).unwrap();
        }
        for w in workers {
            w.join().expect("worker thread");
        }
        let combine: u64 = match topo.tree_shape(world) {
            None => (1..world).map(|r| root.rx_from(r)).sum(),
            Some(s) => (1..s.n_groups()).map(|i| root.rx_from(s.group_range(i).start)).sum(),
        };
        let total: u64 = (0..world).map(|r| root.rx_from(r)).sum();
        (combine, total)
    };

    let (star_combine, star_total) = run(Topology::Star);
    assert_eq!(star_combine, rounds * 8 * ef_frame, "star: (n−1) uploads per round");
    assert_eq!(star_total, star_combine);

    let (tree_combine, tree_total) = run(Topology::Tree { group: 3 });
    assert_eq!(tree_combine, rounds * 2 * ef_frame, "tree3: (G−1) leader partials per round");
    // + the root's own group-0 members (the leader-leg cost every
    // leader pays, regardless of topology depth)
    assert_eq!(tree_total, rounds * 4 * ef_frame);
    assert_eq!(tree_combine * (world as u64 - 1), star_combine * 2, "(G−1)/(n−1) ratio");
}

#[test]
fn weighted_table_and_sweep_server_legs_agree_bitwise() {
    // The root leg folds λ_i = |group i|/n into the combine. The
    // weighted pattern table and the weighted sweep must produce the
    // same bits (same prefix-doubling association as the unweighted
    // ISSUE 5 contract), and a constant weight closure must reproduce
    // the unweighted builder exactly.
    use zo_adam::comm::compress::{
        accumulate_words, build_sign_table, build_sign_table_weighted, compress, table_lookup,
        transpose_sign_words,
    };
    use zo_adam::tensor::Rng;

    let d = 4 * 64 + 13;
    let n = 5usize;
    let mut rng = Rng::new(42);
    let uploads: Vec<_> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 1.0);
            compress(&v)
        })
        .collect();
    // the n=5, g=2 root-leg weights: λ = {2,2,1}/5 padded per upload
    let weights: Vec<f32> = vec![2.0 / 5.0, 2.0 / 5.0, 2.0 / 5.0, 2.0 / 5.0, 1.0 / 5.0];

    let mut sweep = vec![0.0f32; d];
    for (w, u) in uploads.iter().enumerate() {
        accumulate_words(&u.signs, u.scale, weights[w], &mut sweep);
    }
    let mut table = Vec::new();
    build_sign_table_weighted(n, |w| weights[w], |w| uploads[w].scale, &mut table);
    let mut pattern = vec![0u16; d];
    transpose_sign_words(n, |w, k| uploads[w].signs[k], &mut pattern);
    let mut looked = vec![0.0f32; d];
    table_lookup(&table, &pattern, &mut looked);
    for j in 0..d {
        assert_eq!(sweep[j].to_bits(), looked[j].to_bits(), "j={j}");
    }

    let inv = 1.0 / n as f32;
    let mut t1 = Vec::new();
    build_sign_table(n, inv, |w| uploads[w].scale, &mut t1);
    let mut t2 = Vec::new();
    build_sign_table_weighted(n, |_| inv, |w| uploads[w].scale, &mut t2);
    assert_eq!(t1.len(), t2.len());
    for (a, b) in t1.iter().zip(&t2) {
        assert_eq!(a.to_bits(), b.to_bits(), "constant weight must equal the unweighted builder");
    }
}

#[test]
fn mismatched_topology_launch_fails_fast_with_a_typed_error() {
    // Two processes launched with different --topology spellings have
    // different spec fingerprints (the spelling is normalized, then
    // hashed), so the root rejects the worker at the handshake — a
    // typed error naming the cause, not a deadlocked collective.
    use zo_adam::comm::TransportError;
    let world = 3;
    let root_spec = spec("01adam", 256, 4, world, Topology::Tree { group: 2 });
    let worker_spec = spec("01adam", 256, 4, world, Topology::Star);
    // same args, same world — ONLY the topology differs, and it is
    // enough to change the fingerprint
    assert_ne!(root_spec.fingerprint(), worker_spec.fingerprint());

    let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let root_fp = root_spec.fingerprint();
    let root = std::thread::spawn(move || {
        Tcp::root_topo(listener, world, root_fp, Topology::Tree { group: 2 })
    });
    // rank 1 joins with the wrong topology; rank 2 never shows up —
    // the root must still fail fast on the fingerprint, not time out
    let worker = Tcp::connect_topo(&addr, 1, world, worker_spec.fingerprint(), Topology::Star);
    match root.join().expect("root thread") {
        Ok(_) => panic!("root accepted a worker with a mismatched topology fingerprint"),
        Err(TransportError::Handshake(msg)) => {
            assert!(msg.contains("fingerprint"), "unexpected handshake error: {msg}")
        }
        Err(other) => panic!("expected a handshake rejection, got {other:?}"),
    }
    assert!(worker.is_err(), "the mismatched worker must not come up");
}
