//! ISSUE 4 acceptance: a multi-rank run over a **real transport**
//! (in-proc channels and loopback TCP, plus actual spawned worker
//! processes through the CLI) produces bitwise-identical parameters,
//! per-step losses, evaluations and ledger round counts to the
//! single-process `ExecMode::Threaded(N)` engine — for every optimizer
//! family. This is the subsystem's core contract (DESIGN.md
//! §Transport): the codec, the error-feedback state on every rank, the
//! fp16 wire and the sync policies are exercised end-to-end the way a
//! deployment would run them, and nothing about the trajectory changes.

use zo_adam::comm::transport::tcp::Tcp;
use zo_adam::comm::transport::RankLink;
use zo_adam::comm::{onebit_payload_bytes, HEADER_BYTES, SERVER_CHUNK};
use zo_adam::coordinator::distributed::FAMILIES;
use zo_adam::coordinator::{check_parity, launch_inproc, run_local, run_rank, DistSpec, ExecMode};

fn spec(family: &str, d: usize, steps: u64, world: usize) -> DistSpec {
    DistSpec {
        family: family.to_string(),
        d,
        steps,
        world,
        seed: 7,
        lr: 0.01,
        kappa: 4.0,
        sigma: 0.15,
        init: 0.8,
        ..DistSpec::default()
    }
}

#[test]
fn four_inproc_ranks_are_bitwise_threaded4_for_every_family() {
    // d spans two codec chunks and sits off the 64-bit words, so the
    // chunked server leg, ragged sign words and the fp16 wire all see
    // their multi-chunk paths; 12 steps cross 1-bit Adam's T0 and
    // several 0/1 Adam syncs.
    let d = 2 * SERVER_CHUNK + 777;
    for family in FAMILIES {
        let spec = spec(family, d, 12, 4);
        let results = launch_inproc(&spec).unwrap_or_else(|e| panic!("{family}: {e}"));
        let local = run_local(&spec, ExecMode::Threaded(4));
        check_parity(&results[0], &local).unwrap_or_else(|e| panic!("{family}: {e}"));
        // every rank counted the same rounds
        for r in &results[1..] {
            assert_eq!(r.ledger.fp_rounds, results[0].ledger.fp_rounds, "{family} rank {}", r.rank);
            assert_eq!(
                r.ledger.onebit_rounds, results[0].ledger.onebit_rounds,
                "{family} rank {}",
                r.rank
            );
            assert_eq!(
                r.ledger.bytes_total, results[0].ledger.bytes_total,
                "{family} rank {}",
                r.rank
            );
        }
    }
}

#[test]
fn four_tcp_ranks_are_bitwise_threaded4() {
    // Real loopback sockets for the families with the richest comm
    // schedules: 0/1 Adam (fp rounds + 1-bit syncs + local steps) and
    // 1-bit Adam (fp stage then EF stage).
    for family in ["01adam", "1bit-adam"] {
        let spec = spec(family, SERVER_CHUNK + 321, 10, 4);
        let group = Tcp::loopback_group(4, spec.fingerprint())
            .unwrap_or_else(|e| panic!("{family}: loopback group: {e}"));
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = group
                .into_iter()
                .map(|tp| {
                    let spec = &spec;
                    s.spawn(move || {
                        let mut link = RankLink::new(Box::new(tp));
                        run_rank(&mut link, spec)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread").unwrap_or_else(|e| panic!("{family}: {e}")))
                .collect()
        });
        let local = run_local(&spec, ExecMode::Threaded(4));
        check_parity(&results[0], &local).unwrap_or_else(|e| panic!("{family} over tcp: {e}"));
    }
}

#[test]
fn transport_table_and_sweep_server_legs_agree_bitwise() {
    // ISSUE 5: rank 0 of a transport group runs the same server leg as
    // the in-process engine, so forcing its pattern-table path and its
    // per-worker sweep across two otherwise-identical 3-rank runs must
    // produce identical broadcast bits, identical persistent server
    // error — and both must match the 3-lane in-process reduction.
    use zo_adam::comm::transport::inproc;
    use zo_adam::comm::EfAllReduce;
    use zo_adam::tensor::Rng;

    let d = SERVER_CHUNK + 321;
    let world = 3usize;
    let rounds = 4u64;
    let buf_for = move |rank: usize, round: u64| -> Vec<f32> {
        let mut rng = Rng::new(6000 + (round * world as u64) + rank as u64);
        let mut v = vec![0.0f32; d];
        rng.fill_normal(&mut v, 1.0);
        v
    };

    let run = |force: Option<bool>| -> (Vec<f32>, Vec<f32>) {
        let mut group = inproc::group(world);
        let workers: Vec<_> = group.drain(1..).collect();
        let root_tp = group.pop().expect("rank 0");
        let handles: Vec<_> = workers
            .into_iter()
            .enumerate()
            .map(|(i, tp)| {
                let rank = i + 1;
                std::thread::spawn(move || {
                    let mut link = RankLink::new(Box::new(tp));
                    let mut ef = EfAllReduce::new(1, d);
                    let mut out = vec![0.0f32; d];
                    for round in 0..rounds {
                        let buf = buf_for(rank, round);
                        let refs: Vec<&[f32]> = vec![&buf];
                        ef.reduce_transport(&refs, &mut out, &mut link).unwrap();
                    }
                    out
                })
            })
            .collect();
        let mut link = RankLink::new(Box::new(root_tp));
        let mut ef = EfAllReduce::new(1, d);
        ef.force_server_path(force);
        let mut out = vec![0.0f32; d];
        for round in 0..rounds {
            let buf = buf_for(0, round);
            let refs: Vec<&[f32]> = vec![&buf];
            ef.reduce_transport(&refs, &mut out, &mut link).unwrap();
        }
        for h in handles {
            let w_out = h.join().expect("worker rank thread");
            for j in 0..d {
                assert_eq!(w_out[j].to_bits(), out[j].to_bits(), "worker broadcast j={j}");
            }
        }
        (out, ef.server_err.clone())
    };

    let (out_sweep, err_sweep) = run(Some(false));
    let (out_table, err_table) = run(Some(true));
    for j in 0..d {
        assert_eq!(out_sweep[j].to_bits(), out_table[j].to_bits(), "j={j}");
    }
    assert_eq!(err_sweep, err_table, "persistent server error diverged");

    // and both equal the n-lane in-process reduction's trajectory
    let mut local = EfAllReduce::new(world, d);
    let mut out_local = vec![0.0f32; d];
    for round in 0..rounds {
        let bufs: Vec<Vec<f32>> = (0..world).map(|r| buf_for(r, round)).collect();
        local.reduce(&bufs, &mut out_local);
    }
    for j in 0..d {
        assert_eq!(out_local[j].to_bits(), out_table[j].to_bits(), "local vs transport j={j}");
    }
    assert_eq!(local.server_err, err_table);
}

#[test]
fn distributed_ledger_counts_actual_framed_bytes() {
    // The ISSUE 4 wiring claim: under a transport the ledger counts
    // header + payload per direction — exactly, per round kind.
    let d = 1500;
    let spec = spec("01adam-nolocal", d, 6, 3);
    let results = launch_inproc(&spec).unwrap();
    let ledger = &results[0].ledger;
    let fp_frame = (HEADER_BYTES + 2 * d) as u64; // fp16 payload
    let ef_frame = (HEADER_BYTES + onebit_payload_bytes(d)) as u64;
    let want = ledger.fp_rounds * 2 * fp_frame + ledger.onebit_rounds * 2 * ef_frame;
    assert_eq!(ledger.bytes_total, want, "framed-byte accounting must be exact");
    // and the analytic in-process run charges strictly less (no
    // headers, tight bit packing)
    let local = run_local(&spec, ExecMode::Sequential);
    assert!(local.ledger.bytes_total < ledger.bytes_total);
}

#[test]
fn two_ranks_with_different_dims_fail_typed_not_wrong() {
    // A rank trained with the wrong --d must produce a typed dim
    // mismatch, not a corrupted reduction.
    use zo_adam::comm::TransportError;
    let good = spec("adam", 256, 4, 2);
    let mut bad = good.clone();
    bad.d = 128;
    let links = zo_adam::comm::transport::inproc::group(2);
    let errs: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = links
            .into_iter()
            .enumerate()
            .map(|(rank, tp)| {
                let run_spec = if rank == 0 { good.clone() } else { bad.clone() };
                s.spawn(move || {
                    let mut link = RankLink::new(Box::new(tp));
                    run_rank(&mut link, &run_spec)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
    });
    let failed = errs.iter().filter(|r| r.is_err()).count();
    assert!(failed >= 1, "dim mismatch must fail at least one rank");
    let has_typed = errs.iter().any(|r| {
        matches!(
            r,
            Err(TransportError::DimMismatch { .. })
                | Err(TransportError::PayloadSize { .. })
                | Err(TransportError::Closed { .. })
                | Err(TransportError::Truncated { .. })
        )
    });
    assert!(has_typed, "failure must be a typed transport error");
}

#[test]
fn multiprocess_tcp_launch_binary_smoke() {
    // The full deployment shape: `zo-adam launch --transport tcp`
    // spawns real `zo-adam worker` OS processes over loopback and
    // verifies bitwise parity against the in-process engine itself
    // (--check-parity exits non-zero on any mismatch).
    let exe = env!("CARGO_BIN_EXE_zo-adam");
    let out = std::process::Command::new(exe)
        .args([
            "launch",
            "--ranks",
            "3",
            "--transport",
            "tcp",
            "--family",
            "01adam",
            "--d",
            "1500",
            "--steps",
            "8",
            "--check-parity",
            "--quiet",
        ])
        .output()
        .expect("run zo-adam launch");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "launch failed ({}):\nstdout:\n{stdout}\nstderr:\n{stderr}",
        out.status
    );
    assert!(stdout.contains("PARITY OK"), "missing parity line:\n{stdout}");
}
