//! Wire-protocol property tests (ISSUE 4 satellite): frames round-trip
//! arbitrary codec payloads bit-exactly, and every corruption /
//! truncation / reorder / shape-mismatch class is a **typed**
//! `TransportError` — never a panic and never a silently wrong answer.

use zo_adam::comm::transport::{
    decode_frame, decode_header, encode_frame, FrameHeader, FrameKind, TransportError,
    HEADER_BYTES, MAX_PAYLOAD,
};
use zo_adam::testkit::{property, Gen};

const KINDS: [FrameKind; 10] = [
    FrameKind::Hello,
    FrameKind::Barrier,
    FrameKind::FpF16,
    FrameKind::FpF32,
    FrameKind::Ef,
    FrameKind::Loss,
    FrameKind::Bye,
    FrameKind::EfPartial,
    FrameKind::FpPartial,
    FrameKind::Resume,
];

fn arbitrary_header(g: &mut Gen) -> FrameHeader {
    FrameHeader::new(
        *g.choose(&KINDS),
        g.usize_in(0..64),
        g.u64_in(0..u64::MAX / 2),
        g.usize_in(0..1 << 20),
        g.usize_in(0..1 << 16),
    )
}

fn arbitrary_payload(g: &mut Gen) -> Vec<u8> {
    // codec-shaped payloads: raw bytes incl. f32 scales / u64 words
    let len = g.usize_in(0..2048);
    (0..len).map(|_| g.u64_in(0..256) as u8).collect()
}

#[test]
fn prop_frame_roundtrip_is_bit_exact() {
    property(120, |g: &mut Gen| {
        let header = arbitrary_header(g);
        let payload = arbitrary_payload(g);
        let mut bytes = Vec::new();
        encode_frame(header, &payload, &mut bytes);
        assert_eq!(bytes.len(), HEADER_BYTES + payload.len());

        let mut back = Vec::new();
        let got = decode_frame(&bytes, &mut back).expect("well-formed frame decodes");
        assert_eq!(got.kind, header.kind);
        assert_eq!(got.rank, header.rank);
        assert_eq!(got.seq, header.seq);
        assert_eq!(got.dim, header.dim);
        assert_eq!(got.chunk, header.chunk);
        assert_eq!(got.payload_len as usize, payload.len());
        assert_eq!(back, payload, "payload must survive bit-exactly");

        // and the header block alone round-trips through decode_header
        let head: [u8; HEADER_BYTES] = bytes[..HEADER_BYTES].try_into().unwrap();
        let h2 = decode_header(&head).unwrap();
        assert_eq!(h2.payload_len as usize, payload.len());
    });
}

#[test]
fn prop_truncated_frames_are_typed_errors() {
    property(120, |g: &mut Gen| {
        let header = arbitrary_header(g);
        let payload = arbitrary_payload(g);
        let mut bytes = Vec::new();
        encode_frame(header, &payload, &mut bytes);
        // every strict prefix fails Truncated — never panics, never
        // yields a frame
        let cut = g.usize_in(0..bytes.len());
        let mut sink = Vec::new();
        match decode_frame(&bytes[..cut], &mut sink) {
            Err(TransportError::Truncated { .. }) => {}
            other => panic!("prefix of {cut}/{} bytes: {other:?}", bytes.len()),
        }
        // trailing garbage is also rejected (frames are exact units)
        bytes.push(0x5a);
        match decode_frame(&bytes, &mut sink) {
            Err(TransportError::PayloadSize { .. }) => {}
            other => panic!("trailing byte accepted: {other:?}"),
        }
    });
}

#[test]
fn prop_corrupted_headers_are_typed_errors() {
    property(120, |g: &mut Gen| {
        let header = arbitrary_header(g);
        let payload = arbitrary_payload(g);
        let mut bytes = Vec::new();
        encode_frame(header, &payload, &mut bytes);
        let mut sink = Vec::new();

        // bad magic
        let mut b = bytes.clone();
        b[g.usize_in(0..4)] ^= 0xff;
        assert!(matches!(
            decode_frame(&b, &mut sink),
            Err(TransportError::BadMagic { .. })
        ));

        // bad version
        let mut b = bytes.clone();
        b[4] = 0xee;
        assert!(matches!(
            decode_frame(&b, &mut sink),
            Err(TransportError::BadVersion { got: _ })
        ));

        // unknown kind
        let mut b = bytes.clone();
        b[6] = 0x7f;
        b[7] = 0x7f;
        assert!(matches!(
            decode_frame(&b, &mut sink),
            Err(TransportError::BadKind { .. })
        ));

        // absurd payload length
        let mut b = bytes.clone();
        b[28..36].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(
            decode_frame(&b, &mut sink),
            Err(TransportError::Oversize { .. })
        ));
    });
}

#[test]
fn prop_corrupted_payloads_are_typed_errors() {
    // Version 2 (ISSUE 10): the header stamps an FNV-1a over the
    // payload; flipping ANY payload byte — or any stamped-digest byte —
    // must surface as PayloadCorrupt, never as a silently wrong
    // reduction.
    property(120, |g: &mut Gen| {
        let header = arbitrary_header(g);
        let mut payload = arbitrary_payload(g);
        if payload.is_empty() {
            payload.push(g.u64_in(0..256) as u8);
        }
        let mut bytes = Vec::new();
        encode_frame(header, &payload, &mut bytes);
        let mut sink = Vec::new();

        // flip one payload byte
        let mut b = bytes.clone();
        let i = HEADER_BYTES + g.usize_in(0..payload.len());
        b[i] ^= 1 << g.usize_in(0..8);
        assert!(matches!(
            decode_frame(&b, &mut sink),
            Err(TransportError::PayloadCorrupt { .. })
        ));

        // flip one stamped-digest byte (header bytes 36..44)
        let mut b = bytes.clone();
        b[36 + g.usize_in(0..8)] ^= 1 << g.usize_in(0..8);
        assert!(matches!(
            decode_frame(&b, &mut sink),
            Err(TransportError::PayloadCorrupt { .. })
        ));
    });
}

#[test]
fn prop_schedule_mismatches_are_typed_errors() {
    // FrameHeader::expect is the receiver-side schedule validator:
    // reordered seq, wrong sender, wrong dim, wrong chunk association
    // and wrong kind each map to their own error.
    property(120, |g: &mut Gen| {
        let kind = *g.choose(&KINDS);
        let from = g.usize_in(0..32);
        let seq = g.u64_in(0..1 << 40);
        let dim = g.usize_in(0..1 << 20);
        let chunk = g.usize_in(0..1 << 16);
        let header = FrameHeader::new(kind, from, seq, dim, chunk);

        header.expect(kind, from, seq, dim, chunk).expect("matching frame passes");

        let wrong_kind = *g.choose(&KINDS.iter().filter(|k| **k != kind).cloned().collect::<Vec<_>>());
        assert!(matches!(
            header.expect(wrong_kind, from, seq, dim, chunk),
            Err(TransportError::KindMismatch { .. })
        ));
        assert!(matches!(
            header.expect(kind, from + 1, seq, dim, chunk),
            Err(TransportError::RankMismatch { .. })
        ));
        // a reordered / replayed round
        assert!(matches!(
            header.expect(kind, from, seq + g.u64_in(1..9), dim, chunk),
            Err(TransportError::SeqMismatch { .. })
        ));
        assert!(matches!(
            header.expect(kind, from, seq, dim + 1, chunk),
            Err(TransportError::DimMismatch { .. })
        ));
        assert!(matches!(
            header.expect(kind, from, seq, dim, chunk + 64),
            Err(TransportError::ChunkMismatch { .. })
        ));
    });
}

#[test]
fn partial_kinds_have_pinned_wire_values() {
    // The tree's leader-combine kinds and the reconnect handshake are
    // wire protocol now: their u16 values must never drift (an old
    // binary would decode a new frame as BadKind, not as the wrong
    // collective — or worse, treat a data frame as a Resume).
    for (kind, want) in [
        (FrameKind::EfPartial, 8u16),
        (FrameKind::FpPartial, 9u16),
        (FrameKind::Resume, 10u16),
    ] {
        let header = FrameHeader::new(kind, 3, 5, 64, 0);
        let mut bytes = Vec::new();
        encode_frame(header, &[], &mut bytes);
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), want, "{kind:?}");
        let mut sink = Vec::new();
        assert_eq!(decode_frame(&bytes, &mut sink).unwrap().kind, kind);
    }
}

#[test]
fn member_hello_outside_the_group_is_group_mismatch() {
    // The leader-side handshake validator: a rank whose group (under
    // the *receiver's* topology) is led by someone else gets a typed
    // GroupMismatch — the mismatched `--topology` failure mode surfaces
    // as an error naming both ranks, never a mis-wired edge.
    use zo_adam::comm::transport::tcp::validate_member;
    use zo_adam::comm::Topology;
    let world = 9;
    let fp: u64 = 0xd00d;
    let shape = Topology::Tree { group: 4 }.tree_shape(world).unwrap();
    let hello = |rank: usize| {
        FrameHeader::new(FrameKind::Hello, rank, 0, world, zo_adam::comm::compress::CODEC_CHUNK)
    };
    // ranks 5..8 belong to leader 4
    validate_member(&hello(5), &fp.to_le_bytes(), world, fp, shape, 4).unwrap();
    for (rank, leader) in [(5usize, 8usize), (8, 4), (4, 4), (1, 4)] {
        let err = validate_member(&hello(rank), &fp.to_le_bytes(), world, fp, shape, leader)
            .unwrap_err();
        match err {
            TransportError::GroupMismatch { leader: l, rank: r } => {
                assert_eq!((l as usize, r as usize), (leader, rank));
            }
            other => panic!("rank {rank} at leader {leader}: {other:?}"),
        }
    }
    // ...and a fingerprint mismatch still loses to the handshake check,
    // now as the structured variant carrying both fingerprints
    assert!(matches!(
        validate_member(&hello(5), &fp.to_le_bytes(), world, 0xbad, shape, 4),
        Err(TransportError::FingerprintMismatch { want: 0xbad, got: 0xd00d })
    ));
}

#[test]
fn reordered_frames_over_a_real_channel_are_rejected() {
    // Two frames sent out of schedule order over the in-proc backend:
    // the receiver's expect() flags the first frame it sees as a seq
    // mismatch instead of reducing with stale data.
    use zo_adam::comm::transport::{inproc, Transport};
    let mut eps = inproc::group(2);
    let mut w = eps.pop().unwrap();
    let mut root = eps.pop().unwrap();
    let h = std::thread::spawn(move || {
        // the schedule says seq 1 comes first; send seq 2's frame first
        w.send(0, FrameHeader::new(FrameKind::Loss, 1, 2, 1, 0), &1.0f32.to_le_bytes())
            .unwrap();
        w.send(0, FrameHeader::new(FrameKind::Loss, 1, 1, 1, 0), &2.0f32.to_le_bytes())
            .unwrap();
    });
    let mut payload = Vec::new();
    let header = root.recv(1, &mut payload).unwrap();
    let err = header.expect(FrameKind::Loss, 1, 1, 1, 0).unwrap_err();
    assert!(matches!(err, TransportError::SeqMismatch { want: 1, got: 2 }), "{err}");
    h.join().unwrap();
}
