//! TESTKIT_SEED env-var replay, tested in its own process: this binary
//! contains exactly one test, so mutating the process-global
//! environment cannot race with other `property` callers (the lib's
//! unit tests run multithreaded and must never see a transient replay
//! var — see testkit::tests).

use zo_adam::testkit::{case_seed, property, DEFAULT_BASE_SEED};

#[test]
fn property_reads_testkit_seed_env_for_exact_replay() {
    let seed = case_seed(DEFAULT_BASE_SEED, 23);

    // Without the var: the full schedule runs, starting at case 0.
    let first = std::sync::Mutex::new(Vec::new());
    property(3, |g| first.lock().unwrap().push(g.case_seed));
    assert_eq!(first.lock().unwrap().len(), 3);
    assert_eq!(first.lock().unwrap()[0], case_seed(DEFAULT_BASE_SEED, 0));

    // With the var: exactly one case, exactly that seed (decimal form).
    std::env::set_var("TESTKIT_SEED", seed.to_string());
    let seen = std::sync::Mutex::new(Vec::new());
    property(50, |g| seen.lock().unwrap().push(g.case_seed));
    assert_eq!(*seen.lock().unwrap(), vec![seed]);

    // Hex form, as printed by the failure report.
    std::env::set_var("TESTKIT_SEED", format!("{seed:#x}"));
    let seen_hex = std::sync::Mutex::new(Vec::new());
    property(50, |g| seen_hex.lock().unwrap().push(g.case_seed));
    assert_eq!(*seen_hex.lock().unwrap(), vec![seed]);

    std::env::remove_var("TESTKIT_SEED");
}
