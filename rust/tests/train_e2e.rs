//! End-to-end integration: real training through the full stack
//! (PJRT gradients → distributed optimizers → coordinator) must reduce
//! the LM loss, keep worker consensus, and produce sane evaluations.

use zo_adam::config::BERT_BASE;
use zo_adam::exp::convergence::{run_convergence, ConvOpts};
use zo_adam::exp::Algo;
use zo_adam::runtime::Runtime;

fn artifacts() -> Option<Runtime> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json")
        .exists()
        .then(|| Runtime::new(&dir).unwrap())
}

#[test]
fn zeroone_adam_trains_the_tiny_lm() {
    let Some(rt) = artifacts() else { return };
    let mut opts = ConvOpts::quick(&BERT_BASE, 120);
    opts.workers = 2;
    opts.log_every = 10;
    let runs = run_convergence(&rt, &opts, &[Algo::ZeroOneAdam]).unwrap();
    let (_, res) = &runs[0];
    let first = res.log.records.first().unwrap().loss;
    let last = res.log.tail_loss(3).unwrap();
    // init loss ≈ ln(256) ≈ 5.55; must visibly descend in 120 steps
    assert!(first > 5.0, "unexpected init loss {first}");
    assert!(last < first - 0.5, "no descent: {first} -> {last}");
    // eval on held-out stream also improved from uniform
    assert!(res.final_eval.unwrap() < first as f32);
    // comm pattern: short run is mostly warmup, volume must be well
    // below Adam's 16 bits/param but nonzero
    let bpp = res.ledger.bits_per_param();
    assert!(bpp > 0.1 && bpp < 4.0, "bits/param {bpp}");
}

#[test]
fn all_three_algorithms_reach_similar_loss() {
    let Some(rt) = artifacts() else { return };
    let mut opts = ConvOpts::quick(&BERT_BASE, 150);
    opts.workers = 2;
    let runs = run_convergence(&rt, &opts, &Algo::main_three()).unwrap();
    let finals: Vec<(Algo, f64)> = runs
        .iter()
        .map(|(a, r)| (*a, r.log.tail_loss(5).unwrap()))
        .collect();
    let best = finals.iter().map(|(_, l)| *l).fold(f64::MAX, f64::min);
    let worst = finals.iter().map(|(_, l)| *l).fold(0.0, f64::max);
    assert!(worst < 5.0, "some algo failed to descend: {finals:?}");
    // Figure-2 parity: at 150 steps transient dynamics still differ
    // (1-bit Adam's early frozen variance takes larger steps); longer
    // runs converge to the same loss (see bench_fig2 / quickstart).
    assert!(worst - best < 1.2, "parity violated: {finals:?}");
    // volume ordering: adam > 1bit > 0/1
    let vol = |a: Algo| {
        runs.iter()
            .find(|(x, _)| *x == a)
            .unwrap()
            .1
            .ledger
            .bits_per_param()
    };
    assert!(vol(Algo::Adam) > vol(Algo::OneBitAdam));
    assert!(vol(Algo::OneBitAdam) > vol(Algo::ZeroOneAdam));
}

#[test]
fn mlp_proxy_accuracy_beats_chance_quickly() {
    let Some(rt) = artifacts() else { return };
    let acc =
        zo_adam::exp::tables::imagenet_proxy_accuracy(&rt, Algo::ZeroOneAdam, 600, 2).unwrap();
    // 100 classes => chance = 1%; with the calibrated separability
    // (signal 0.14) 600 steps should sit several times above chance
    // (the full Table-2 run reaches ~64% at 1500 steps × 4 workers).
    assert!(acc > 0.05, "top-1 {acc}");
}
