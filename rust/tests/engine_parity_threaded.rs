//! Tentpole acceptance: `ExecMode::Threaded(n)` must be **bitwise
//! identical** to `ExecMode::Sequential` through the whole stack —
//! gradients, every optimizer's local/reduce phases, the volume ledger
//! and the simulated cluster clock — for every optimizer family, for
//! random dims (including non-multiples of 64), worker counts, thread
//! counts and sync policies.
//!
//! Determinism contract under test: DESIGN.md §3.

use zo_adam::comm::ETHERNET;
use zo_adam::coordinator::{ExecMode, NoObserver, RunResult, Trainer, TrainerConfig};
use zo_adam::grad::synthetic::NoisyQuadratic;
use zo_adam::optim::policy::{SyncPolicy, SyncSchedule, VarPolicy, VarSchedule};
use zo_adam::optim::{
    Adam, ConstLr, DistOptimizer, FrozenVarAdam, Hyper, MomentumSgd, NaiveOneBitAdam, SignSgd,
    ZeroOneAdam,
};
use zo_adam::testkit::{property, Gen};

/// Everything we pin bit-for-bit between the two modes.
fn assert_bitwise_equal(a: &RunResult, b: &RunResult, ctx: &str) {
    assert_eq!(a.final_params.len(), b.final_params.len(), "{ctx}: dim");
    for (j, (x, y)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: final_params[{j}]");
    }
    // volume ledger
    assert_eq!(a.ledger.steps, b.ledger.steps, "{ctx}: ledger.steps");
    assert_eq!(a.ledger.fp_rounds, b.ledger.fp_rounds, "{ctx}: fp_rounds");
    assert_eq!(a.ledger.onebit_rounds, b.ledger.onebit_rounds, "{ctx}: onebit_rounds");
    assert_eq!(a.ledger.skipped_steps, b.ledger.skipped_steps, "{ctx}: skipped");
    assert_eq!(a.ledger.bytes_total, b.ledger.bytes_total, "{ctx}: bytes");
    // simulated clock
    assert_eq!(a.sim_total_s.to_bits(), b.sim_total_s.to_bits(), "{ctx}: sim clock");
    // per-record trace: losses, lr, wire bytes, step clock
    assert_eq!(a.log.records.len(), b.log.records.len(), "{ctx}: record count");
    for (ra, rb) in a.log.records.iter().zip(&b.log.records) {
        assert_eq!(ra.t, rb.t, "{ctx}: record t");
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{ctx}: loss@t={}", ra.t);
        assert_eq!(ra.lr.to_bits(), rb.lr.to_bits(), "{ctx}: lr@t={}", ra.t);
        assert_eq!(ra.wire_bytes, rb.wire_bytes, "{ctx}: wire@t={}", ra.t);
        assert_eq!(ra.sim_ms.to_bits(), rb.sim_ms.to_bits(), "{ctx}: sim_ms@t={}", ra.t);
        assert_eq!(ra.synced, rb.synced, "{ctx}: synced@t={}", ra.t);
        assert_eq!(ra.var_updated, rb.var_updated, "{ctx}: var@t={}", ra.t);
        match (ra.eval_loss, rb.eval_loss) {
            (None, None) => {}
            (Some(ea), Some(eb)) => {
                assert_eq!(ea.to_bits(), eb.to_bits(), "{ctx}: eval@t={}", ra.t)
            }
            _ => panic!("{ctx}: eval presence differs at t={}", ra.t),
        }
    }
}

/// The five optimizer families under test.
const FAMILIES: [&str; 6] =
    ["adam", "momentum-sgd", "signsgd-ef", "naive-1bit-adam", "1bit-adam", "01adam"];

fn build(family: &str, d: usize, n: usize, lr: f64, g: &mut Gen, steps: u64) -> Box<dyn DistOptimizer> {
    let init = vec![0.8f32; d];
    let h = Hyper::default();
    match family {
        "adam" => Box::new(Adam::new(init, n, h, Box::new(ConstLr(lr)))),
        "momentum-sgd" => Box::new(MomentumSgd::new(init, n, 0.9, Box::new(ConstLr(lr)))),
        "signsgd-ef" => Box::new(SignSgd::new(init, n, Box::new(ConstLr(lr)))),
        "naive-1bit-adam" => Box::new(NaiveOneBitAdam::new(init, n, h, Box::new(ConstLr(lr)))),
        "1bit-adam" => {
            let t0 = g.u64_in(0..steps.max(2));
            Box::new(FrozenVarAdam::onebit_adam(init, n, h, Box::new(ConstLr(lr)), t0))
        }
        "01adam" => {
            let var = match g.usize_in(0..3) {
                0 => VarPolicy::Always,
                1 => VarPolicy::ExpInterval { kappa: g.usize_in(1..6) as u32 },
                _ => VarPolicy::OneShot { t0: g.u64_in(1..steps.max(2)) },
            };
            let sync = match g.usize_in(0..3) {
                0 => SyncPolicy::Always,
                1 => SyncPolicy::Fixed { interval: g.u64_in(1..6) },
                _ => SyncPolicy::IntervalDoubling {
                    warmup: g.u64_in(1..steps.max(2)),
                    double_every: g.u64_in(1..steps.max(2)),
                    clip: 1 << g.usize_in(0..5),
                },
            };
            Box::new(ZeroOneAdam::new(
                init,
                n,
                h,
                Box::new(ConstLr(lr)),
                VarSchedule::new(var),
                SyncSchedule::new(sync),
            ))
        }
        other => panic!("unknown family {other}"),
    }
}

fn run(
    family: &str,
    d: usize,
    n: usize,
    lr: f64,
    steps: u64,
    src_seed: u64,
    exec: ExecMode,
    g: &mut Gen,
) -> RunResult {
    let mut src = NoisyQuadratic::new(d, 4.0, 0.15, src_seed);
    let mut opt = build(family, d, n, lr, g, steps);
    let cfg = TrainerConfig {
        steps,
        log_every: 1,
        eval_every: (steps / 3).max(1),
        fabric: Some(ETHERNET),
        sim_gpus: 32,
        compute_ms: 2.5,
        exec,
        ..Default::default()
    };
    Trainer::run(&mut src, opt.as_mut(), &cfg, &mut NoObserver)
}

#[test]
fn prop_threaded_is_bitwise_sequential_for_every_optimizer() {
    property(10, |g: &mut Gen| {
        // dims straddle the 64-wide codec words on purpose
        let d = g.usize_in(1..200);
        let n = g.usize_in(1..6);
        let steps = g.u64_in(3..20);
        let threads = g.usize_in(2..9);
        let lr = g.f64_in(1e-3, 5e-2);
        let src_seed = g.case_seed ^ 0x5151;
        for family in FAMILIES {
            // The optimizer builder draws policy parameters from the
            // generator; replay the same draws for both modes.
            let mut ga = Gen::new(g.case_seed ^ 0xabcd);
            let mut gb = Gen::new(g.case_seed ^ 0xabcd);
            let a = run(family, d, n, lr, steps, src_seed, ExecMode::Sequential, &mut ga);
            let b = run(family, d, n, lr, steps, src_seed, ExecMode::Threaded(threads), &mut gb);
            let ctx = format!(
                "{family} d={d} n={n} steps={steps} threads={threads} seed={:#x}",
                g.case_seed
            );
            assert_bitwise_equal(&a, &b, &ctx);
        }
    });
}

#[test]
fn chunked_server_reduction_is_bitwise_sequential_for_every_family() {
    // ISSUE 2: the EF server leg is chunk-parallel over fixed
    // SERVER_CHUNK-coordinate pieces. Dims here cross several chunks
    // (and sit off the 64-bit codec words), so the ranged accumulate /
    // sign-pack / finish kernels and the chunk-ordered f64 ‖·‖₁ combine
    // are all exercised through every optimizer family, end to end
    // through Trainer::run — params, ledger, trace and clock pinned
    // bit for bit.
    let chunk = zo_adam::comm::SERVER_CHUNK;
    for &d in &[chunk + 1, 2 * chunk + 777, 3 * chunk] {
        for family in FAMILIES {
            let mut ga = Gen::new(0x7e57 ^ d as u64);
            let mut gb = Gen::new(0x7e57 ^ d as u64);
            let a = run(family, d, 3, 0.01, 8, 41, ExecMode::Sequential, &mut ga);
            let b = run(family, d, 3, 0.01, 8, 41, ExecMode::Threaded(4), &mut gb);
            assert_bitwise_equal(&a, &b, &format!("{family} d={d} (multi-chunk)"));
        }
    }
}

#[test]
fn oversubscribed_pool_is_bitwise_sequential() {
    // ISSUE 3 pool coverage: pool widths far beyond the host's cores
    // (CI boxes have 2) — scheduling pressure and preemption must not
    // leak into results. MAX_POOL_THREADS is the clamp width, i.e. the
    // widest pool an engine will ever build.
    use zo_adam::coordinator::MAX_POOL_THREADS;
    let d = 2 * zo_adam::comm::SERVER_CHUNK + 321; // multi-chunk, off-word
    for threads in [16usize, MAX_POOL_THREADS] {
        for family in ["adam", "01adam"] {
            let mut ga = Gen::new(0xbeef ^ threads as u64);
            let mut gb = Gen::new(0xbeef ^ threads as u64);
            let a = run(family, d, 3, 0.01, 6, 91, ExecMode::Sequential, &mut ga);
            let b = run(family, d, 3, 0.01, 6, 91, ExecMode::Threaded(threads), &mut gb);
            assert_bitwise_equal(&a, &b, &format!("{family} oversubscribed threads={threads}"));
        }
    }
}

#[test]
fn more_threads_than_chunks_is_bitwise_sequential() {
    // Tiny dims: every parallel region has fewer chunks (and fewer
    // worker replicas) than pool lanes, so most of the pool idles each
    // epoch — results must not care.
    for &d in &[1usize, 3, 64, 130] {
        for family in FAMILIES {
            let mut ga = Gen::new(0x1d1e ^ d as u64);
            let mut gb = Gen::new(0x1d1e ^ d as u64);
            let a = run(family, d, 2, 0.02, 6, 17, ExecMode::Sequential, &mut ga);
            let b = run(family, d, 2, 0.02, 6, 17, ExecMode::Threaded(16), &mut gb);
            assert_bitwise_equal(&a, &b, &format!("{family} d={d} threads>chunks"));
        }
    }
}

#[test]
fn pool_reuse_across_runs_and_drop_rebuild_cycles() {
    // Back-to-back training runs: within one run the trainer reuses a
    // single engine for thousands of regions (every step is several),
    // and across runs the engine — pool included — is dropped and
    // rebuilt. Results stay pinned to fresh sequential replays through
    // every cycle.
    for cycle in 0..3u64 {
        let mut ga = Gen::new(0xd0_0d ^ cycle);
        let mut gb = Gen::new(0xd0_0d ^ cycle);
        let a = run("01adam", 777, 3, 0.01, 15, 400 + cycle, ExecMode::Sequential, &mut ga);
        let b = run("01adam", 777, 3, 0.01, 15, 400 + cycle, ExecMode::Threaded(5), &mut gb);
        assert_bitwise_equal(&a, &b, &format!("pool rebuild cycle {cycle}"));
    }
}

#[test]
fn threaded8_matches_sequential_on_a_longer_zeroone_run() {
    // The acceptance configuration called out in the issue: 8 threads,
    // 8 materialized workers, the paper 0/1 Adam policy shapes.
    let d = 1337; // non-multiple of 64, > one chunk at tiny floors
    let n = 8;
    let run = |exec: ExecMode| {
        let mut src = NoisyQuadratic::new(d, 5.0, 0.1, 77);
        let mut opt = ZeroOneAdam::new(
            vec![1.0; d],
            n,
            Hyper::default(),
            Box::new(ConstLr(0.01)),
            VarSchedule::paper(),
            SyncSchedule::new(SyncPolicy::IntervalDoubling {
                warmup: 20,
                double_every: 30,
                clip: 8,
            }),
        );
        let cfg = TrainerConfig {
            steps: 120,
            log_every: 1,
            eval_every: 40,
            fabric: Some(ETHERNET),
            sim_gpus: 128,
            compute_ms: 1.0,
            exec,
            ..Default::default()
        };
        Trainer::run(&mut src, &mut opt, &cfg, &mut NoObserver)
    };
    let a = run(ExecMode::Sequential);
    let b = run(ExecMode::Threaded(8));
    assert_bitwise_equal(&a, &b, "01adam long run");
    // and the run actually trained
    let first = a.log.records.first().unwrap().loss;
    let last = a.log.tail_loss(5).unwrap();
    assert!(last < first, "no descent: {first} -> {last}");
}
