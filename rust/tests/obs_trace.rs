//! Flight-recorder contracts at the run level (ISSUE 9):
//!
//! 1. **Tracing changes nothing** — a traced in-proc launch is bitwise
//!    identical to an untraced one (final params, per-step losses,
//!    ledger). The recorder only carries timestamps *out*; nothing
//!    flows back into arithmetic.
//! 2. **Tracing is deterministic** — two same-seed traced runs record
//!    identical per-rank event sequences once timestamps are stripped
//!    (the phases and their order are part of the reproducible
//!    trajectory; only the nanoseconds differ).
//! 3. **The exported stream is well-formed** — it survives a
//!    parse → render → parse round-trip and passes the same `check`
//!    that `zo-adam trace --check` holds ci.sh's traced smoke to.

use zo_adam::coordinator::{launch_inproc, launch_inproc_opts, DistSpec, RankOpts};
use zo_adam::obs::{events, parse_jsonl, render_jsonl, EventKind, PhaseId, Record};

fn small_spec() -> DistSpec {
    // 1-bit Adam at 12 steps: T₀ = (12/8).max(2) = 2 full-precision
    // warmup rounds, then compressed EF rounds — both leg families are
    // guaranteed to appear in the trace.
    DistSpec {
        family: "1bit-adam".to_string(),
        d: 450,
        steps: 12,
        world: 3,
        ..DistSpec::default()
    }
}

/// A unique, pre-cleaned temp path (the exporter *appends*).
fn temp_trace(tag: &str) -> String {
    let path = std::env::temp_dir()
        .join(format!("zo_adam_obs_trace_{tag}_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    path.to_string_lossy().to_string()
}

fn traced_opts(path: &str) -> RankOpts {
    RankOpts { trace_out: Some(path.to_string()), ..RankOpts::default() }
}

/// The timestamp-free identity of one phase event.
fn phase_key(r: &Record) -> Option<(usize, EventKind, PhaseId, u64)> {
    match r {
        Record::Phase { rank, kind, phase, arg, .. } => Some((*rank, *kind, *phase, *arg)),
        _ => None,
    }
}

#[test]
fn traced_run_is_bitwise_identical_to_untraced() {
    let spec = small_spec();
    let plain = launch_inproc(&spec).expect("untraced launch");
    let path = temp_trace("parity");
    let traced = launch_inproc_opts(&spec, &traced_opts(&path)).expect("traced launch");

    let (p0, t0) = (&plain[0], &traced[0]);
    assert_eq!(p0.final_params.len(), t0.final_params.len());
    for (j, (a, b)) in p0.final_params.iter().zip(&t0.final_params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "final_params[{j}] diverged under tracing");
    }
    assert_eq!(p0.losses.len(), t0.losses.len());
    for (t, (a, b)) in p0.losses.iter().zip(&t0.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "loss@t={t} diverged under tracing");
    }
    for (p, t) in plain.iter().zip(&traced) {
        assert_eq!(p.ledger.rounds_total(), t.ledger.rounds_total(), "rank {}", p.rank);
        assert_eq!(p.ledger.bytes_total, t.ledger.bytes_total, "rank {}", p.rank);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn same_seed_traced_runs_record_identical_event_sequences() {
    let spec = small_spec();
    let (pa, pb) = (temp_trace("det_a"), temp_trace("det_b"));
    launch_inproc_opts(&spec, &traced_opts(&pa)).expect("first traced launch");
    launch_inproc_opts(&spec, &traced_opts(&pb)).expect("second traced launch");
    let ra = parse_jsonl(&std::fs::read_to_string(&pa).unwrap()).unwrap();
    let rb = parse_jsonl(&std::fs::read_to_string(&pb).unwrap()).unwrap();

    // Rank chunks may land in the file in either completion order, so
    // compare per rank; within a rank the recorder preserves program
    // order, which must replay exactly.
    for rank in 0..spec.world {
        let ka: Vec<_> =
            ra.iter().filter(|r| r.rank() == rank).filter_map(phase_key).collect();
        let kb: Vec<_> =
            rb.iter().filter(|r| r.rank() == rank).filter_map(phase_key).collect();
        assert!(!ka.is_empty(), "rank {rank} recorded no phase events");
        assert_eq!(ka, kb, "rank {rank}: event sequences diverged between same-seed runs");

        // The non-phase records agree too, timestamps aside.
        let steps = |rs: &[Record]| -> Vec<(u64, u64)> {
            rs.iter()
                .filter(|r| r.rank() == rank)
                .filter_map(|r| match r {
                    Record::Step { t, loss, .. } => Some((*t, loss.to_bits())),
                    _ => None,
                })
                .collect()
        };
        assert_eq!(steps(&ra), steps(&rb), "rank {rank}: step records diverged");
    }
    let _ = std::fs::remove_file(&pa);
    let _ = std::fs::remove_file(&pb);
}

#[test]
fn trace_file_passes_check_and_round_trips() {
    let spec = small_spec();
    let path = temp_trace("check");
    launch_inproc_opts(&spec, &traced_opts(&path)).expect("traced launch");
    let text = std::fs::read_to_string(&path).unwrap();
    let records = parse_jsonl(&text).unwrap();

    let summary = events::check(&records).unwrap_or_else(|e| panic!("check failed: {e}"));
    assert_eq!(summary.ranks, vec![0, 1, 2], "every rank flushed a stream");
    assert!(summary.spans > 0, "closed spans recorded");
    assert!(summary.phase_events as u64 >= summary.spans * 2);
    // one Meta / Round / Recovery per rank
    for rank in 0..spec.world {
        for (name, want) in [("meta", 1), ("round", 1), ("recovery", 1)] {
            let got = records
                .iter()
                .filter(|r| r.rank() == rank)
                .filter(|r| match r {
                    Record::Meta { .. } => name == "meta",
                    Record::Round { .. } => name == "round",
                    Record::Recovery { .. } => name == "recovery",
                    _ => false,
                })
                .count();
            assert_eq!(got, want, "rank {rank}: {name} records");
        }
    }
    // the worker legs actually showed up in the trace
    for phase in [PhaseId::Step, PhaseId::FpRound, PhaseId::Compress, PhaseId::Barrier] {
        assert!(
            records.iter().filter_map(phase_key).any(|(_, _, p, _)| p == phase),
            "no {} events in the stream",
            phase.name()
        );
    }

    let back = parse_jsonl(&render_jsonl(&records)).unwrap();
    assert_eq!(back, records, "JSONL round-trip");
    let _ = std::fs::remove_file(&path);
}
