//! Bitwise parity of the fused hot-path codec kernels against naive
//! references (ISSUE 2 satellite): `accumulate_into`/`accumulate_words`
//! vs dense decompress + scalar multiply-add, and the fused server
//! kernels (`fold_err_signs_l1` + `ef_finish_words`) vs the two-pass
//! `compress_with_error_into` + `decompress_into` path — across
//! off-word lengths, ±0 scales, negative weights and random sign
//! patterns. ISSUE 5 adds the pattern-table server accumulation
//! (`build_sign_table` + `transpose_sign_words` + `table_lookup`)
//! against the n-pass ordered `accumulate_words` chain it replaces.

use zo_adam::comm::compress::{self, OneBit};
use zo_adam::testkit::{property, Gen};

/// A OneBit with arbitrary (not compression-produced) sign words and
/// scale — exercises patterns the codec itself would never emit.
fn arbitrary_onebit(g: &mut Gen, d: usize) -> OneBit {
    let mut c = OneBit::zeros(d);
    for w in c.signs.iter_mut() {
        *w = (g.u64_in(0..u64::MAX) << 1) | g.u64_in(0..2);
    }
    c.scale = match g.usize_in(0..6) {
        0 => 0.0,
        1 => -0.0,
        2 => f32::MIN_POSITIVE, // subnormal boundary
        _ => g.f32_in(1e-6, 3.0),
    };
    c
}

#[test]
fn prop_accumulate_matches_decompress_scalar_add_bitwise() {
    property(40, |g: &mut Gen| {
        let d = g.usize_in(1..300); // straddles the 64-bit words
        let c = arbitrary_onebit(g, d);
        let weight = match g.usize_in(0..5) {
            0 => 0.0,
            1 => -0.0,
            2 => -g.f32_in(0.1, 2.0), // negative weights too
            _ => g.f32_in(1e-3, 2.0),
        };
        // Strictly nonzero base: a −0.0 scale (never produced by the
        // codec, but allowed by the wire format) collapses both signs of
        // the broadcast to −0.0, and `x + (−0.0)` vs `x + (+0.0)` differ
        // bitwise only at x = −0.0 exactly.
        let base = g.vec_f32(d..d + 1, 0.25, 1.75);

        // naive reference: dense decompress, then out += weight * dec
        let mut dec = vec![0.0f32; d];
        compress::decompress_into(&c, &mut dec);
        let mut want = base.clone();
        for (o, &v) in want.iter_mut().zip(&dec) {
            *o += weight * v;
        }

        let mut got = base.clone();
        compress::accumulate_into(&c, weight, &mut got);
        for j in 0..d {
            assert_eq!(
                got[j].to_bits(),
                want[j].to_bits(),
                "d={d} j={j} scale={} weight={weight}",
                c.scale
            );
        }
    });
}

#[test]
fn prop_fused_server_kernels_match_two_pass_reference() {
    property(30, |g: &mut Gen| {
        let d = g.usize_in(1..520);
        let acc = g.vec_normal(d..d + 1, 1.0); // the worker-accumulated sum
        let err = g.vec_normal(d..d + 1, 0.5); // the server error δ̄

        // Reference: s = acc + err materialized, then the two-pass
        // compress_with_error_into + decompress_into server leg.
        let s_ref: Vec<f32> = acc.iter().zip(&err).map(|(a, b)| a + b).collect();
        let mut ref_packed = OneBit::zeros(d);
        let mut ref_err = err.clone();
        compress::compress_with_error_into(&s_ref, &mut ref_packed, &mut ref_err);
        let mut ref_out = vec![0.0f32; d];
        compress::decompress_into(&ref_packed, &mut ref_out);

        // Fused path, as reduce_eng drives it over one whole-tensor
        // chunk: fold (accumulate err + sign-pack + L1), combine, finish.
        let mut s = acc.clone();
        let mut words = vec![0u64; d.div_ceil(64)];
        let l1 = compress::fold_err_signs_l1(&mut s, &err, &mut words);
        let scale = (l1 / d as f64) as f32;
        assert_eq!(scale.to_bits(), ref_packed.scale.to_bits(), "scale d={d}");
        assert_eq!(words, ref_packed.signs, "signs d={d}");
        let mut new_err = vec![0.0f32; d];
        let mut out = vec![0.0f32; d];
        compress::ef_finish_words(&s, &words, scale.to_bits(), &mut new_err, &mut out);
        for j in 0..d {
            assert_eq!(out[j].to_bits(), ref_out[j].to_bits(), "out d={d} j={j}");
            assert_eq!(new_err[j].to_bits(), ref_err[j].to_bits(), "err d={d} j={j}");
        }
    });
}

#[test]
fn prop_chunked_lane_kernels_match_fused_compress_ef_bitwise() {
    // ISSUE 3 lane chunking: evaluating the EF worker leg as
    // independent CODEC_CHUNK-range folds (combined in chunk order)
    // plus ranged finishes must equal the fused whole-tensor
    // `compress_ef_into` bit for bit — the property that lets the
    // engine chunk *inside* a lane without breaking seq/threaded
    // parity. Dims cross several chunks and sit off the 64-bit words.
    property(12, |g: &mut Gen| {
        let chunk = compress::CODEC_CHUNK;
        let d = g.usize_in(1..2 * chunk + 500);
        let z = g.vec_normal(d..d + 1, 1.0);
        let err0 = g.vec_normal(d..d + 1, 0.4);

        let mut ref_err = err0.clone();
        let mut ref_packed = OneBit::zeros(d);
        compress::compress_ef_into(&z, &mut ref_err, &mut ref_packed);

        // chunked schedule, driven by hand exactly as reduce_eng's
        // lane-chunked path drives it
        let mut err = err0.clone();
        let mut words = vec![0u64; d.div_ceil(64)];
        let mut l1 = 0.0f64;
        for start in (0..d).step_by(chunk) {
            let end = (start + chunk).min(d);
            l1 += compress::ef_fold_signs_l1(
                &z[start..end],
                &mut err[start..end],
                &mut words[start / 64..end.div_ceil(64)],
            );
        }
        let scale = (l1 / d as f64) as f32;
        assert_eq!(scale.to_bits(), ref_packed.scale.to_bits(), "scale d={d}");
        assert_eq!(words, ref_packed.signs, "signs d={d}");
        for start in (0..d).step_by(chunk) {
            let end = (start + chunk).min(d);
            let word0 = start / 64;
            compress::ef_err_finish_words(&mut err[start..end], &words[word0..], scale.to_bits());
        }
        for j in 0..d {
            assert_eq!(err[j].to_bits(), ref_err[j].to_bits(), "err d={d} j={j}");
        }
    });
}

#[test]
fn prop_sign_table_path_matches_ordered_accumulate_chain_bitwise() {
    // ISSUE 5 tentpole: the single-sweep table path (build the
    // 2^n-entry chain-replay table, bit-transpose the sign words,
    // store table[pattern]) must equal the n-pass `accumulate_words`
    // chain over a zeroed target bit for bit — with arbitrary
    // (wire-decodable, never-codec-produced) sign words, ±0 and
    // subnormal scales, zero and negative weights, random n up to
    // TABLE_BITS and d off the 64-bit words.
    property(30, |g: &mut Gen| {
        let n = g.usize_in(1..compress::TABLE_BITS + 1);
        let d = g.usize_in(1..300);
        let uploads: Vec<OneBit> = (0..n).map(|_| arbitrary_onebit(g, d)).collect();
        let weight = match g.usize_in(0..5) {
            0 => 0.0,
            1 => -0.0,
            2 => -g.f32_in(0.1, 2.0),
            _ => 1.0 / n as f32, // the server leg's actual weight
        };

        let mut sweep = vec![0.0f32; d];
        for u in &uploads {
            compress::accumulate_words(&u.signs, u.scale, weight, &mut sweep);
        }

        let mut table = Vec::new();
        compress::build_sign_table(n, weight, |w| uploads[w].scale, &mut table);
        assert_eq!(table.len(), 1 << n);
        let mut pattern = vec![0u16; d];
        compress::transpose_sign_words(n, |w, k| uploads[w].signs[k], &mut pattern);
        let mut got = vec![f32::NAN; d]; // lookup stores, never reads the target
        compress::table_lookup(&table, &pattern, &mut got);
        for j in 0..d {
            assert_eq!(got[j].to_bits(), sweep[j].to_bits(), "n={n} d={d} j={j} weight={weight}");
        }
    });
}

#[test]
fn prop_transpose_recovers_every_sign_bit() {
    // The transpose is pure bit routing: pattern[i] bit w must equal
    // worker w's sign bit for coordinate i, with no stray high bits.
    property(30, |g: &mut Gen| {
        let n = g.usize_in(1..compress::TABLE_BITS + 1);
        let d = g.usize_in(1..520);
        let uploads: Vec<OneBit> = (0..n).map(|_| arbitrary_onebit(g, d)).collect();
        let mut pattern = vec![0u16; d];
        compress::transpose_sign_words(n, |w, k| uploads[w].signs[k], &mut pattern);
        for i in 0..d {
            for (w, u) in uploads.iter().enumerate() {
                let bit = (u.signs[i / 64] >> (i % 64)) & 1;
                assert_eq!((pattern[i] >> w) as u64 & 1, bit, "n={n} d={d} i={i} w={w}");
            }
            if n < 16 {
                assert_eq!(pattern[i] >> n, 0, "n={n} d={d} i={i}: stray high bits");
            }
        }
    });
}

#[test]
fn prop_accumulate_words_agrees_on_word_aligned_subranges() {
    // The ranged kernel over [64k, d) must equal the whole-tensor
    // kernel restricted to that range — the property the chunk-parallel
    // server leg depends on.
    property(30, |g: &mut Gen| {
        let d = g.usize_in(65..700);
        let c = arbitrary_onebit(g, d);
        let weight = g.f32_in(0.01, 1.5);
        let base = g.vec_normal(d..d + 1, 1.0);

        let mut whole = base.clone();
        compress::accumulate_into(&c, weight, &mut whole);

        let cut_words = g.usize_in(1..d / 64 + 1); // ≥ 1 word offset
        let cut = cut_words * 64;
        let mut tail = base[cut..].to_vec();
        compress::accumulate_words(&c.signs[cut_words..], c.scale, weight, &mut tail);
        for (j, t) in tail.iter().enumerate() {
            assert_eq!(
                t.to_bits(),
                whole[cut + j].to_bits(),
                "d={d} cut={cut} j={j}"
            );
        }
    });
}
