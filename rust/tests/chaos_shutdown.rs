//! ISSUE 7 satellite: graceful shutdown when a **real OS process**
//! dies mid-round. `zo-adam launch --kill-rank R` arms one worker to
//! `abort()` at a given step; the launch must then fail with a typed
//! diagnosis naming the dead rank, do so within the deadline budget
//! (no survivor blocks past its recv deadline + resume window), and
//! leave **zero** live worker processes — the same guarantee
//! `tests/launch_cleanup.rs` pins for bootstrap-time failures,
//! extended here to mid-training death.

use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

fn exe() -> &'static str {
    env!("CARGO_BIN_EXE_zo-adam")
}

/// A seed value unlikely to collide with any other test's workers: it
/// shows up verbatim in each worker's argv (`--seed <marker>`), so a
/// /proc cmdline scan can find survivors of *this* launch only.
const MARKER_SEED: &str = "424243777";

/// Count live processes whose cmdline contains both `worker` and the
/// marker seed (Linux only; elsewhere returns 0 and the assertion is
/// vacuous, matching launch_cleanup.rs's liveness gating).
fn surviving_workers() -> usize {
    if !cfg!(target_os = "linux") {
        return 0;
    }
    let Ok(entries) = std::fs::read_dir("/proc") else { return 0 };
    entries
        .flatten()
        .filter(|e| e.file_name().to_string_lossy().chars().all(|c| c.is_ascii_digit()))
        .filter(|e| {
            std::fs::read(e.path().join("cmdline"))
                .map(|raw| {
                    let cmdline = String::from_utf8_lossy(&raw).replace('\0', " ");
                    cmdline.contains("worker") && cmdline.contains(MARKER_SEED)
                })
                .unwrap_or(false)
        })
        .count()
}

#[test]
fn killed_rank_fails_the_launch_typed_bounded_and_leaves_no_survivors() {
    let t0 = Instant::now();
    let out = Command::new(exe())
        .args([
            "launch",
            "--ranks",
            "4",
            "--transport",
            "tcp",
            "--kill-rank",
            "2",
            "--kill-at-step",
            "3",
            "--recv-deadline",
            "3",
            "--resume-window",
            "1",
            "--d",
            "512",
            "--steps",
            "40",
            "--seed",
            MARKER_SEED,
            "--quiet",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .output()
        .expect("run zo-adam launch");
    let elapsed = t0.elapsed();
    let stderr = String::from_utf8_lossy(&out.stderr);
    let stdout = String::from_utf8_lossy(&out.stdout);

    assert!(
        !out.status.success(),
        "a launch whose rank 2 aborted must fail\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    // The diagnosis must name the dead rank (the worker-status note
    // and/or the chaos abort line), not just echo the root's symptom.
    assert!(stderr.contains("rank 2"), "stderr must name the dead rank:\n{stderr}");
    // Budget: rank 2 dies within a step or two; the root notices at
    // its next read from it (≤ one 3 s recv deadline), burns at most
    // one 1 s resume window waiting for a reconnect that never comes,
    // then shuts the survivors down under a 2 s grace. 40 s is that
    // worst case with an order of magnitude of host-noise headroom —
    // the old failure mode was minutes of silent blocking.
    assert!(
        elapsed < Duration::from_secs(40),
        "launch took {elapsed:?} to fail — survivors overslept their deadlines"
    );
    assert_eq!(
        surviving_workers(),
        0,
        "a failed launch left live `zo-adam worker` processes behind"
    );
}
