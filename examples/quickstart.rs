//! Quickstart: train a tiny transformer LM with 0/1 Adam across 4
//! simulated workers, entirely from Rust (Python only built the
//! artifacts). ~20 seconds on a laptop-class CPU.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use zo_adam::config::BERT_BASE;
use zo_adam::exp::convergence::{run_convergence, ConvOpts};
use zo_adam::exp::Algo;
use zo_adam::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. The PJRT runtime loads the AOT artifacts (HLO text lowered by
    //    python/compile/aot.py — transformer fwd/bwd + Pallas kernels).
    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    // 2. Configure a short 0/1 Adam pretraining run: 4 workers, paper
    //    policies (adaptive variance freezing + LR-tracked local steps)
    //    scaled to the run length.
    let mut opts = ConvOpts::quick(&BERT_BASE, 300);
    opts.workers = 4;
    opts.verbose = true;
    opts.log_every = 25;

    // 3. Train, and compare against the original-Adam baseline.
    let runs = run_convergence(&rt, &opts, &[Algo::ZeroOneAdam, Algo::Adam])?;
    println!();
    for (algo, res) in &runs {
        println!(
            "{:<8}  loss {:.3} -> {:.3} | eval {:.3} | {:.3} bits/param | {} comm rounds | sim(128 GPUs, ethernet) {:.2} h",
            algo.name(),
            res.log.records.first().unwrap().loss,
            res.log.tail_loss(3).unwrap(),
            res.final_eval.unwrap_or(f32::NAN),
            res.ledger.bits_per_param(),
            res.ledger.rounds_total(),
            res.sim_total_s / 3600.0,
        );
    }

    let zo = &runs[0].1;
    let adam = &runs[1].1;
    println!(
        "\n0/1 Adam matched Adam's loss within {:.3} while sending {:.0}x less data.",
        (zo.log.tail_loss(3).unwrap() - adam.log.tail_loss(3).unwrap()).abs(),
        adam.ledger.bits_per_param() / zo.ledger.bits_per_param()
    );
    Ok(())
}
