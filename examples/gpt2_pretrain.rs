//! GPT-2 pretraining scenario (Figure 6 + Table 2 LM columns).
//!
//! Compares 1-bit Adam and 0/1 Adam on the GPT proxy (causal LM over a
//! Markov corpus), reporting training loss and validation perplexity
//! against tokens consumed, plus the zero-shot-style evaluations
//! (perplexity + final-token cloze accuracy).
//!
//! ```text
//! cargo run --release --example gpt2_pretrain -- --steps 1000
//! ```

use zo_adam::benchkit::Table;
use zo_adam::config::GPT2;
use zo_adam::eval::{perplexity, LmEvaluator};
use zo_adam::exp::convergence::{run_convergence, ConvOpts};
use zo_adam::exp::Algo;
use zo_adam::runtime::Runtime;
use zo_adam::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let p = Args::new("gpt2_pretrain", "GPT-2 proxy pretraining (Figure 6)")
        .opt("steps", "1000", "training steps")
        .opt("workers", "4", "simulated workers")
        .opt("model", "lm_tiny", "proxy model (lm_tiny|lm_small|lm_medium)")
        .parse_env();

    let rt = Runtime::new("artifacts")?;
    let mut opts = ConvOpts::quick(&GPT2, p.get_u64("steps"));
    opts.model = p.get("model").to_string();
    opts.workers = p.get_usize("workers");
    opts.sim_gpus = 64; // the paper uses 64 GPUs for GPT-2
    opts.verbose = true;

    let entry = rt.manifest.model(&opts.model)?;
    let tokens_per_step =
        (entry.cfg("batch")? * (entry.cfg("seq_len")? - 1) * opts.workers) as u64;

    let runs = run_convergence(&rt, &opts, &[Algo::OneBitAdam, Algo::ZeroOneAdam])?;

    println!("\n== Figure 6 — loss / val perplexity vs tokens ==");
    for (algo, res) in &runs {
        println!("\n--- {} ---", algo.name());
        for r in res.log.records.iter().step_by((res.log.records.len() / 12).max(1)) {
            let ppl = r.eval_loss.map(|l| format!("{:8.2}", l.exp())).unwrap_or_else(|| "   -".into());
            println!(
                "tokens {:>9}  loss {:.4}  val-ppl {ppl}",
                (r.t + 1) * tokens_per_step,
                r.loss
            );
        }
        res.log
            .write_csv(format!("results/gpt2_pretrain_{}.csv", algo.name()))?;
    }

    println!();
    let evaluator = LmEvaluator::new(&rt, &opts.model, opts.seed)?;
    let mut t = Table::new(
        "Table 2 (LM columns) — zero-shot proxy evaluation",
        &["algo", "wikitext-proxy ppl", "lambada-proxy acc %", "tokens seen"],
    );
    for (algo, res) in &runs {
        let loss = evaluator.eval_loss(&res.final_params, 8)?;
        let cloze = evaluator.cloze_accuracy(&res.final_params, 8)?;
        t.row(vec![
            algo.name().to_string(),
            format!("{:.2}", perplexity(loss)),
            format!("{:.2}", cloze * 100.0),
            (opts.steps * tokens_per_step).to_string(),
        ]);
    }
    t.print();
    Ok(())
}
