//! End-to-end system validation: the full three-layer stack on the
//! largest bundled model.
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end: it proves
//! that all layers compose —
//!   L1  Pallas optimizer kernels (validated against the Rust native
//!       step engine at startup, via PJRT execution),
//!   L2  the AOT transformer train-step (real gradients, real loss),
//!   L3  the Rust coordinator (workers, EF-1-bit AllReduce, T_v/T_u
//!       policies, volume ledger, simulated cluster clock)
//! — by pretraining the `lm_medium` transformer (≈6.9M params; pass
//! `--model lm_small|lm_tiny` for quicker runs) for a few hundred steps
//! of 0/1 Adam on the synthetic corpus and logging the loss curve.
//!
//! ```text
//! cargo run --release --example e2e_train -- --steps 300 --workers 2
//! ```

use zo_adam::config::BERT_LARGE;
use zo_adam::exp::convergence::{run_convergence, ConvOpts};
use zo_adam::exp::Algo;
use zo_adam::runtime::{golden_vec, HostTensor, Runtime};
use zo_adam::util::cli::Args;

/// Cross-layer check: execute the L1 Pallas `zo_local_step` kernel via
/// PJRT and compare element-wise against the L3 native step math.
fn verify_kernel_vs_native(rt: &Runtime, model: &str) -> anyhow::Result<f32> {
    let d = rt.manifest.model(model)?.param_count;
    let beta1 = rt.manifest.beta1 as f32;
    let (g, m, x, u) = (
        golden_vec(d, 0.3, 0.1),
        golden_vec(d, 1.1, 0.05),
        golden_vec(d, 3.7, 1.0),
        golden_vec(d, 4.9, 0.02),
    );
    let rsv: Vec<f32> = golden_vec(d, 2.3, 0.2)
        .iter()
        .map(|v| 1.0 / (v.abs() + 1e-3f32).sqrt())
        .collect();
    let gamma = 1e-3f32;

    let exe = rt.load(model, "zo_local_step")?;
    let outs = exe.run(&[
        HostTensor::f32(vec![gamma], &[1]),
        HostTensor::f32(g.clone(), &[d]),
        HostTensor::f32(m.clone(), &[d]),
        HostTensor::f32(x.clone(), &[d]),
        HostTensor::f32(u.clone(), &[d]),
        HostTensor::f32(rsv.clone(), &[d]),
    ])?;

    // Native (L3) math — the same fused loop ZeroOneAdam::step runs.
    let mut max_err = 0.0f32;
    let (km, kx, ku) = (outs[0].as_f32()?, outs[1].as_f32()?, outs[2].as_f32()?);
    for i in 0..d {
        let m_new = beta1 * m[i] + (1.0 - beta1) * g[i];
        let step = gamma * m_new;
        max_err = max_err
            .max((km[i] - m_new).abs())
            .max((kx[i] - (x[i] - step * rsv[i])).abs())
            .max((ku[i] - (u[i] + step)).abs());
    }
    Ok(max_err)
}

fn main() -> anyhow::Result<()> {
    let p = Args::new("e2e_train", "end-to-end three-layer validation run")
        .opt("model", "lm_medium", "model artifact (lm_tiny|lm_small|lm_medium)")
        .opt("steps", "300", "training steps")
        .opt("workers", "2", "simulated workers")
        .opt("algo", "01adam", "optimizer")
        .parse_env();

    let rt = Runtime::new("artifacts")?;
    let model = p.get("model").to_string();
    let entry = rt.manifest.model(&model)?;
    println!(
        "e2e: model={model} d={} ({} tensors), platform={}",
        entry.param_count,
        entry.layout.len(),
        rt.platform()
    );

    // Step 0: cross-layer kernel validation.
    let err = verify_kernel_vs_native(&rt, &model)?;
    println!("L1-vs-L3 kernel check: max elementwise error {err:.2e}");
    anyhow::ensure!(err < 1e-5, "Pallas kernel diverged from native engine");

    // Steps 1..N: the real training run.
    let algo = Algo::by_name(p.get("algo")).expect("algo");
    let mut opts = ConvOpts::quick(&BERT_LARGE, p.get_u64("steps"));
    opts.model = model.clone();
    opts.workers = p.get_usize("workers");
    opts.verbose = true;
    opts.log_every = (opts.steps / 30).max(1);
    opts.eval_every = (opts.steps / 6).max(1);

    let runs = run_convergence(&rt, &opts, &[algo])?;
    let (_, res) = &runs[0];
    let csv = format!("results/e2e_{}_{}.csv", model, algo.name());
    res.log.write_csv(&csv)?;

    let first = res.log.records.first().unwrap().loss;
    let last = res.log.tail_loss(5).unwrap();
    println!("\n=== end-to-end summary ===");
    println!("loss: {first:.4} -> {last:.4} over {} steps", opts.steps);
    println!("held-out eval loss: {:?}", res.final_eval);
    println!(
        "comm: {:.3} bits/param, {} rounds ({} fp + {} 1-bit), {:.1}% steps communicated",
        res.ledger.bits_per_param(),
        res.ledger.rounds_total(),
        res.ledger.fp_rounds,
        res.ledger.onebit_rounds,
        res.ledger.comm_step_fraction() * 100.0
    );
    println!(
        "simulated 128-GPU Ethernet time: {:.2} h | actual wall: {:.1}s",
        res.sim_total_s / 3600.0,
        res.wall_s
    );
    println!("loss curve: {csv}");
    anyhow::ensure!(last < first - 0.05, "training did not reduce the loss");
    println!("ALL LAYERS COMPOSE ✓");
    Ok(())
}
