//! ImageNet/ResNet18 proxy scenario (Figure 2d/3d + Table 2 column 1).
//!
//! Trains the MLP image classifier on synthetic Gaussian-blob classes
//! with all three optimizers, reports top-1 accuracy parity and the
//! simulated small-cluster throughput sweep (the paper runs ImageNet on
//! 4–32 GPUs because the model/batch are small).
//!
//! ```text
//! cargo run --release --example imagenet_resnet_proxy -- --steps 1500
//! ```

use zo_adam::benchkit::Table;
use zo_adam::comm::ETHERNET;
use zo_adam::config::IMAGENET;
use zo_adam::exp::convergence::{run_convergence, ConvOpts};
use zo_adam::exp::{tables, Algo};
use zo_adam::grad::hlo::HloMlpSource;
use zo_adam::runtime::Runtime;
use zo_adam::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let p = Args::new("imagenet_resnet_proxy", "ImageNet proxy training")
        .opt("steps", "1500", "training steps")
        .opt("workers", "4", "simulated workers")
        .parse_env();

    let rt = Runtime::new("artifacts")?;
    let mut opts = ConvOpts::quick(&IMAGENET, p.get_u64("steps"));
    opts.workers = p.get_usize("workers");
    opts.sim_gpus = 32;
    opts.verbose = true;

    let runs = run_convergence(&rt, &opts, &Algo::main_three())?;

    let mut t = Table::new(
        "Table 2 (ImageNet column) — top-1 accuracy parity",
        &["algo", "top-1 %", "final train loss", "bits/param"],
    );
    for (algo, res) in &runs {
        let mut src = HloMlpSource::new(&rt, &opts.model, opts.seed)?;
        let acc = src.eval_accuracy(&res.final_params, 8);
        t.row(vec![
            algo.name().to_string(),
            format!("{:.2}", acc * 100.0),
            format!("{:.4}", res.log.tail_loss(5).unwrap()),
            format!("{:.3}", res.ledger.bits_per_param()),
        ]);
        res.log
            .write_csv(format!("results/imagenet_proxy_{}.csv", algo.name()))?;
    }
    t.print();

    println!("\n(Figure 3d) simulated throughput sweep, 4–32 GPUs, Ethernet:");
    tables::fig3_throughput(&IMAGENET, &ETHERNET, &[4, 8, 16, 32]).print();
    Ok(())
}
