//! BERT-pretraining scenario (Figures 1 & 2 + Table 1 in one driver).
//!
//! Runs the paper's three-way comparison (Adam vs 1-bit Adam vs 0/1
//! Adam) on the BERT proxy, with the simulated 128-GPU Ethernet clock,
//! then probes the pretrained checkpoints on the GLUE-proxy tasks.
//!
//! ```text
//! cargo run --release --example bert_pretrain -- --steps 1200 [--profile]
//! ```

use zo_adam::benchkit::Table;
use zo_adam::config::BERT_BASE;
use zo_adam::eval::glue::{GlueProxy, GLUE_TASKS};
use zo_adam::exp::convergence::{run_convergence, run_profiling, ConvOpts};
use zo_adam::exp::Algo;
use zo_adam::runtime::Runtime;
use zo_adam::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let p = Args::new("bert_pretrain", "BERT proxy pretraining comparison")
        .opt("steps", "1000", "training steps")
        .opt("workers", "4", "simulated workers")
        .opt("model", "lm_tiny", "proxy model")
        .flag("profile", "also run the Figure-1 moment profiling")
        .flag("glue", "probe checkpoints on GLUE-proxy tasks")
        .parse_env();

    let rt = Runtime::new("artifacts")?;
    let mut opts = ConvOpts::quick(&BERT_BASE, p.get_u64("steps"));
    opts.model = p.get("model").to_string();
    opts.workers = p.get_usize("workers");
    opts.verbose = true;

    if p.get_flag("profile") {
        println!("=== Figure 1: Adam moment profiling ===");
        let rows = run_profiling(&rt, &opts)?;
        for row in rows.iter().step_by((rows.len() / 10).max(1)) {
            println!(
                "t={:<6} |Δv|={:.5}  |v_loc−v|={:.5}  |Δm|={:.5}  |m_loc−m|={:.5}",
                row[0].1, row[1].1, row[2].1, row[3].1, row[4].1
            );
        }
        println!();
    }

    println!("=== Figure 2: convergence comparison ===");
    let runs = run_convergence(&rt, &opts, &Algo::main_three())?;
    let mut t = Table::new(
        "BERT proxy — sample-wise & simulated time-wise",
        &["algo", "final loss", "eval", "bits/param", "sim hours @128GPU-eth"],
    );
    for (algo, res) in &runs {
        res.log
            .write_csv(format!("results/bert_pretrain_{}.csv", algo.name()))?;
        t.row(vec![
            algo.name().to_string(),
            format!("{:.4}", res.log.tail_loss(5).unwrap()),
            format!("{:.4}", res.final_eval.unwrap_or(f32::NAN)),
            format!("{:.3}", res.ledger.bits_per_param()),
            format!("{:.2}", res.sim_total_s / 3600.0),
        ]);
    }
    t.print();

    if p.get_flag("glue") {
        println!("\n=== Table 1: GLUE-proxy probes ===");
        let glue = GlueProxy::new(&rt, &opts.model, 0)?;
        let mut headers: Vec<&str> = vec!["checkpoint"];
        headers.extend(GLUE_TASKS);
        headers.push("Avg");
        let mut t = Table::new("GLUE-proxy dev accuracy", &headers);
        for (algo, res) in &runs {
            let accs = glue.evaluate(&res.final_params)?;
            let avg = accs.iter().sum::<f64>() / accs.len() as f64;
            let mut row = vec![algo.name().to_string()];
            row.extend(accs.iter().map(|a| format!("{:.1}", a * 100.0)));
            row.push(format!("{:.1}", avg * 100.0));
            t.row(row);
        }
        t.print();
    }
    Ok(())
}
